"""Slot scheduler: continuous batching of graph point queries.

`launch/serve.py` demos slot-recycling admission for LM decode; this module
is that loop generalized into a reusable serving layer for ACC graph
queries. The analogy to SIMD-X JIT task management is direct: a bounded
static structure (S query lanes per algorithm, fixed shapes, one compiled
step) absorbs an irregular request stream (arrivals of arbitrary sources
and algorithms), with overflow handled by a bounded queue + backpressure
instead of device-side reallocation.

Pieces:

  * `AlgoPool` — S lanes of `batch_engine.BatchState` for ONE program.
    Admission writes a freshly initialized query into a done lane (a jitted
    column write); one `step()` advances every live lane one iteration;
    harvest extracts converged lanes and frees them. Lanes converge and are
    recycled MID-FLIGHT — queries never wait for the batch.
  * `GraphServer` — per-algorithm pools behind one bounded FIFO request
    queue (`submit` returns False when the queue is full — backpressure for
    the caller to retry/shed), fronted by the LRU `ResultCache`: a hit
    completes the request without touching a pool.

Exactness note: a lane admitted into a half-busy pool sees consensus
push/pull decisions influenced by its batch-mates, so its mode *sequence*
can differ from a solo run; results are still bit-identical for the
idempotent/min programs and pull-only programs served here (see
batch_engine's module docstring for the argument).

Admission fairness: requests queue per (TENANT, ALGORITHM) and each queue
owns a weighted share of the total queue budget (weighted fair queuing at
the admission edge, `weights=` per algorithm x `tenant_weights=` per
tenant) — a hot algorithm exhausts only its own share, and within an
algorithm a hot tenant exhausts only its tenant share, never another's
(ROADMAP "per-tenant quotas"). Lanes are per-pool; free lanes are dealt
round-robin across that algorithm's tenant queues.

Sharded pools: constructed with a `mesh` + per-algorithm `placements`, a
pool's lanes shard across the mesh ('replicated' query sharding or
'edge_sharded' graph partitioning — `serving/placement.py`); the scheduler
drives both pool kinds through the same admit/step/harvest loop.

Telemetry (`telemetry=True` / `trace=`, DESIGN.md §12): the server owns an
`repro.obs.Observability` — request-lifecycle spans (submit -> admit ->
harvest -> complete), per-pool latency/volume histograms, and the engines'
cumulative `BatchState.tele` counters, read back as ONE jit-packed vector
per live pool per pump (`_pack_pump` via the counted `device_fetch`
chokepoint) plus one mode-trace fetch per yielding harvest. Disabled (the
default), every hook is a no-op and no telemetry transfer is ever issued;
`stats()` documents the unified read-only schema.

Streaming graphs: constructed with `delta_cap > 0` the server owns a
`repro.streaming.StreamingGraph`; `apply_updates` absorbs an edge-update
batch, swaps the overlaid views into every pool (traced args — no
recompile), selectively invalidates the LRU by the reverse-reachability
test (optionally refreshing dirty monotone entries incrementally), and
restarts dirtied in-flight lanes on the new graph (DESIGN.md §8).

SLO serving (DESIGN.md §13): `submit(deadline_ms=...)` attaches a per-query
deadline that is accounted end-to-end (missed deadlines are counted and
flagged on completions/spans even without a policy); a `slo=SLOPolicy(...)`
additionally drops already-hopeless queued queries at admission, routes
overflow residual-push queries to a loosened-tolerance degraded shadow pool
under queue pressure, and preempts long-resident lanes — parking their full
metadata columns in the result cache and resuming the fixpoint later via
`reseed_from_residuals`, so preempted work is never thrown away.

Consensus cohorts (`cohorts={'algo': k}`): an algorithm's slot budget is
split across k independent leaf pools sharing ONE compiled step. Each
cohort takes its own push/pull consensus vote, so a single heavy query
holding consensus in pull mode drags only its own (narrower, cheaper)
cohort — the tail-latency isolation fix the ROADMAP demanded, demonstrated
in BENCH_slo.json. (The sharded analogue is `Placement(consensus='local')`.)
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acc import ACCProgram
from repro.core.engine import EngineConfig
from repro.graph.csr import EdgeDelta, Graph, live_degrees
from repro.graph.packing import EllPack
from repro.obs import (
    MODE_NAMES,
    Observability,
    SLO_FIELDS,
    TELE_COMPACT_DENSE,
    TELE_COMPACT_HITS,
    TELE_LEN,
    TELE_MASKED_DENSE,
    default_count_buckets,
    default_latency_buckets,
    device_fetch,
    iters_from_trace,
    skew_ratio,
    tele_dict,
)
from repro.serving import batch_engine as B
from repro.serving.cache import (
    CachedEntry,
    ResultCache,
    make_key,
    served_result,
)
from repro.serving.slo import SLOPolicy, degraded_variant


class QueueFull(Exception):
    """Raised by `submit(..., strict=True)` when the request queue is full."""


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    algo: str
    source: int
    tenant: str = "default"
    #: absolute deadline on the server's monotonic clock, or None — set by
    #: `submit(deadline_ms=...)` (DESIGN.md §13)
    deadline_t: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    algo: str
    source: int
    result: Optional[np.ndarray]  # (n,) primary field; None when dropped
    iterations: int
    from_cache: bool
    #: graph version the result is valid for (the version at completion —
    #: a query queued across an update executes on the newer graph; a clean
    #: lane spanning an update is bitwise valid for both end versions).
    graph_version: int = 0
    tenant: str = "default"
    # -- SLO outcome (DESIGN.md §13) ------------------------------------
    #: finished (or was dropped) after its deadline passed
    deadline_missed: bool = False
    #: shed by policy without a result (`result is None`)
    dropped: bool = False
    #: served from the loosened-tolerance degraded shadow pool
    degraded: bool = False
    #: was preempted at least once before completing
    preempted: bool = False


def default_config(g: Graph, max_iters: int = 4096) -> EngineConfig:
    """Serving-friendly engine config: full frontier cap (dense masks can't
    overflow), a modest push edge budget (the consensus controller pulls on
    heavy iterations anyway, so a lean push buffer keeps light iterations
    cheap)."""
    n, m = g.n_nodes, g.n_edges
    return EngineConfig(
        frontier_cap=n, edge_cap=max(1, min(m, 2 * n)), max_iters=max_iters
    )


#: bounded length of a pool's per-iteration telemetry log (`iter_log`) — a
#: lane resident longer than this loses its OLDEST per-iteration samples
#: (the span's `iters` list keeps alignment via None gaps; see
#: `GraphServer._complete_span`)
OBS_LOG_LEN = 512


@jax.jit
def _pack_pump(st: B.BatchState) -> jnp.ndarray:
    """Pack one pump's pool telemetry into ONE int32 vector so the
    scheduler's per-iteration harvest costs a single device->host transfer
    per pool per pump (never per lane, never per shard): [gmode, union_fe,
    overflow, live_lanes, tele(TELE_LEN + n_shards — the named counters
    followed by the per-shard scan-volume plane), per-lane frontier
    counts(S)]. `log_iter` splits the variable-width tele block by the
    fetched length."""
    head = jnp.stack([
        st.gmode.astype(jnp.int32),
        st.union_fe.astype(jnp.int32),
        st.overflow.astype(jnp.int32),
        jnp.sum(~st.done).astype(jnp.int32),
    ])
    tele = (st.tele if st.tele is not None
            else jnp.zeros((TELE_LEN,), jnp.int32))
    return jnp.concatenate([head, tele, st.count.astype(jnp.int32)])


class _LanePool:
    """Lane bookkeeping shared by the single-device and sharded pools — the
    scheduler drives both kinds through exactly this contract. Subclasses
    provide `state`, `lane_rid`, `slots`, `program`, `result_field`, `cfg`,
    `pack`, and a jitted `_admit(st, source, lane, graph)`."""

    #: telemetry flag + bounded per-iteration log, set up by `_init_obs` in
    #: each concrete pool's ctor
    telemetry = False

    def _init_obs(self, telemetry: bool) -> None:
        self.telemetry = bool(telemetry)
        self.iter_log: deque = deque(maxlen=OBS_LOG_LEN)
        #: pool step count at each lane's (re)admission — the lane's
        #: iteration i ran during pool step `lane_admit_step[lane] + 1 + i`
        self.lane_admit_step: List[int] = [0] * self.slots
        #: host wall clock (time.monotonic) at each lane's (re)admission —
        #: the scheduler's residency measure for SLO decisions; always kept
        #: (cheap host floats), works with telemetry off
        self.lane_admit_t: List[float] = [0.0] * self.slots
        #: iterations a lane had ALREADY run when (re)admitted — 0 normally,
        #: the saved iteration count for a preempt-resumed lane, so span
        #: iteration logs stay aligned (`GraphServer._complete_span`)
        self.lane_it_base: List[int] = [0] * self.slots
        #: EWMA of harvested lanes' resident seconds — the policy's
        #: service-time estimate for hopeless-drop / preemption triggers
        self.ewma_resident_s: Optional[float] = None
        #: push/pull decision audit log (DESIGN.md §14): one host record per
        #: executed iteration carrying the consensus inputs (union volume,
        #: thresholds, overflow) and the decided mode, derived from the
        #: SAME packed sample `log_iter` already fetched — zero extra
        #: transfers
        self.audit_log: deque = deque(maxlen=OBS_LOG_LEN)
        self._audit_prev: Optional[np.ndarray] = None
        self._last_gmode: Optional[int] = None
        #: the consensus controller's volume threshold (batch_engine
        #: `_consensus_mode`: heavy when union_fe > alpha * n_edges or
        #: union_fe > edge_cap or overflow)
        self._audit_alpha_edges = int(self.cfg.alpha * self.g.n_edges)

    def log_iter(self) -> dict:
        """Record one executed pool iteration (call right after `step()`):
        one `device_fetch` of the packed sample, appended to `iter_log`.
        The tele block splits by fetched length into the named counters and
        the per-shard scan plane; the same sample also feeds the decision
        audit log."""
        packed = device_fetch(_pack_pump(self.state))
        tele_w = len(packed) - 4 - self.slots
        entry = {
            "step": self.steps,
            "gmode": int(packed[0]),
            "union_fe": int(packed[1]),
            "overflow": bool(packed[2]),
            "live": int(packed[3]),
            "tele": packed[4:4 + TELE_LEN],
            "shard_edges": packed[4 + TELE_LEN:4 + tele_w],
            "counts": packed[4 + tele_w:],
        }
        self.iter_log.append(entry)
        self._audit_iter(entry)
        return entry

    def _audit_iter(self, entry: dict) -> None:
        """Append this iteration's consensus decision record: the inputs
        the controller saw (post-step union volume vs the alpha / edge-cap
        thresholds, overflow) and the mode it chose for the NEXT iteration,
        plus compact-vs-dense and masked-dense fallback deltas recovered by
        differencing consecutive cumulative tele samples (host ints)."""
        tele = np.asarray(entry["tele"], np.int64)
        prev = self._audit_prev
        d = tele - prev if prev is not None else tele
        self._audit_prev = tele
        gmode = entry["gmode"]
        switched = (self._last_gmode is not None
                    and gmode != self._last_gmode)
        self._last_gmode = gmode
        self.audit_log.append({
            "step": entry["step"],
            "union_fe": entry["union_fe"],
            "overflow": entry["overflow"],
            "alpha_threshold": self._audit_alpha_edges,
            "edge_cap": int(self.cfg.edge_cap),
            "mode": MODE_NAMES.get(gmode, str(gmode)),
            "switched": bool(switched),
            "compact_hits_d": int(d[TELE_COMPACT_HITS]),
            "compact_dense_d": int(d[TELE_COMPACT_DENSE]),
            "masked_dense_d": int(d[TELE_MASKED_DENSE]),
        })

    def free_lanes(self) -> List[int]:
        done = np.asarray(self.state.done)
        return [i for i in range(self.slots)
                if self.lane_rid[i] is None and done[i]]

    def live(self) -> bool:
        return any(r is not None for r in self.lane_rid)

    def admit(self, lane: int, rid: int, source: int) -> None:
        assert self.lane_rid[lane] is None
        self.state = self._admit(
            self.state, jnp.int32(source), jnp.int32(lane),
            self._admit_graph(), self._admit_delta(), self.live_deg,
        )
        self.lane_rid[lane] = rid
        self.lane_admit_step[lane] = self.steps
        self.lane_admit_t[lane] = time.monotonic()
        self.lane_it_base[lane] = 0
        self.engine_queries += 1

    def readmit(self, lane: int, source: int) -> None:
        """Re-initialize a LIVE lane's query from scratch on the current
        graph (same rid, same lane — used when a streaming update dirties an
        in-flight query)."""
        assert self.lane_rid[lane] is not None
        self.state = self._admit(
            self.state, jnp.int32(source), jnp.int32(lane),
            self._admit_graph(), self._admit_delta(), self.live_deg,
        )
        self.lane_admit_step[lane] = self.steps
        self.lane_admit_t[lane] = time.monotonic()
        self.lane_it_base[lane] = 0
        self.engine_queries += 1

    def observe_resident(self, resident_s: float) -> None:
        """Fold one harvested lane's residency into the pool's EWMA
        service-time estimate (host floats only)."""
        prev = self.ewma_resident_s
        self.ewma_resident_s = (
            resident_s if prev is None else 0.8 * prev + 0.2 * resident_s)

    def preempt(self, lane: int) -> dict:
        """Evict a LIVE lane mid-run, returning its full metadata columns,
        executed iteration count, and mode-trace row (host numpy) so the
        scheduler can park the partial state and `admit_resume` it later.

        Only meaningful for residual-push programs, whose invariant holds at
        every iteration: the settled (rank, resid) mass is preserved, so the
        evicted query RESUMES its fixpoint instead of restarting (DESIGN.md
        §13). The lane itself is returned to the free pool (done, inactive,
        empty frontier) and the pool's consensus inputs are recomputed
        without the victim's frontier."""
        assert self.lane_rid[lane] is not None
        st = self.state
        saved = {
            "planes": {k: np.asarray(st.m[k][:, lane]) for k in st.m},
            "it": int(st.it[lane]),
            "trace": np.asarray(st.mode_trace[lane]).copy(),
        }
        active = st.active.at[:, lane].set(False)
        st = st._replace(
            active=active,
            done=st.done.at[lane].set(True),
            count=st.count.at[lane].set(0),
        )
        if st.hot is not None:
            st = st._replace(hot=st.hot.at[:, lane].set(False))
        union_fe, overflow = B._union_volume(self.g.out, self.cfg, active)
        st = st._replace(union_fe=union_fe, overflow=overflow)
        st = st._replace(gmode=B._consensus_mode(
            self.program, self.cfg, self.g.n_edges, st))
        self.state = self._place_state(st)
        self.lane_rid[lane] = None
        return saved

    def admit_resume(self, lane: int, rid: int, saved: dict) -> None:
        """Re-admit a preempted query into a free lane from its saved
        partial state: write the metadata columns back, restore the
        iteration count and mode trace, and re-derive the frontier from the
        FULL residual field via the shared `reseed_from_residuals` path —
        the same contract the streaming resume uses. Other live lanes'
        recomputed frontiers equal their current ones (the active set of a
        residual program is a pure function of the metadata), so this
        perturbs nobody else."""
        from repro.streaming.incremental import reseed_from_residuals

        assert self.lane_rid[lane] is None
        st = self.state
        m = {k: st.m[k].at[:, lane].set(jnp.asarray(saved["planes"][k]))
             for k in st.m}
        st = st._replace(
            m=m,
            done=st.done.at[lane].set(False),
            it=st.it.at[lane].set(saved["it"]),
            mode_trace=st.mode_trace.at[lane].set(jnp.asarray(saved["trace"])),
        )
        st = reseed_from_residuals(self.program, self.cfg, self.g, st, st.m)
        self.state = self._place_state(st)
        self.lane_rid[lane] = rid
        self.lane_admit_step[lane] = self.steps
        self.lane_admit_t[lane] = time.monotonic()
        self.lane_it_base[lane] = int(saved["it"])
        self.engine_queries += 1

    def _refresh_live_deg(self) -> None:
        """Live-degree vector is constant per graph version — count it once
        here (ctor / set_graph) and feed the cached copy to every admission
        instead of scatter-adding all m edges per admitted lane."""
        self.live_deg = live_degrees(self.g.out, self.delta)

    def resume_residual(self, sg, report) -> int:
        """RESUME every live lane of a residual-push pool across a streaming
        update: Maiter-correct the residual planes along the changed
        adjacency columns (`streaming.residual_correct` — valid mid-run, the
        invariant holds at every iteration) and reseed live lanes' frontiers
        from the full corrected residual field. Dirty in-flight queries keep
        their settled mass instead of restarting; clean lanes' corrections
        are identically zero, so their trajectories continue bitwise
        unchanged. Returns the number of live lanes left un-converged (the
        lanes that actually resume work)."""
        from repro.streaming.incremental import (
            reseed_from_residuals,
            residual_correct,
        )

        st = self.state
        prev_m = {k: np.asarray(v) for k, v in st.m.items()}
        m0 = residual_correct(self.program, sg, prev_m, report)
        m = {k: jnp.asarray(v) for k, v in m0.items()}
        st = reseed_from_residuals(self.program, self.cfg, self.g, st, m)
        self.state = self._place_state(st)
        live = [lane for lane, rid in enumerate(self.lane_rid)
                if rid is not None]
        return int(np.sum(np.asarray(st.count)[live] > 0)) if live else 0

    def _place_state(self, st: B.BatchState) -> B.BatchState:
        return st

    #: extra metadata planes to harvest alongside the result — residual
    #: pools set this to their residual field so cached entries carry the
    #: full (rank, resid) resumable state (streaming 3(e), DESIGN.md §11)
    cache_extra_fields: tuple = ()

    def harvest(self) -> List[tuple]:
        """(lane, rid, result, iterations, extras) for every converged lane;
        `extras` is a {field: (n,) np} dict of `cache_extra_fields` planes
        (empty for the plain min/max/pull pools)."""
        if not self.live():
            return []
        done = np.asarray(self.state.done)
        out = []
        for lane, rid in enumerate(self.lane_rid):
            if rid is None or not done[lane]:
                continue
            res = np.asarray(self.state.m[self.result_field][:-1, lane])
            extras = {f: np.asarray(self.state.m[f][:-1, lane])
                      for f in self.cache_extra_fields}
            out.append((lane, rid, res, int(self.state.it[lane]), extras))
            self.lane_rid[lane] = None
        return out

    def _admit_graph(self):
        return self.g

    def _admit_delta(self):
        return self.delta

    def _place_pseg(self, pseg: tuple) -> tuple:
        return pseg

    def _reset_masked_pull_cache(self) -> None:
        """Masked-pull partial caches were computed against the old graph,
        so rebuild them at identity (an overflow rebuild can change slice
        ROW COUNTS — stale pseg shapes would type-mismatch the next step)
        and force the next pull dense."""
        if not (self.cfg.masked_pull and self.state.pull_dense is not None):
            return
        ident = self.program.combiner.identity(
            self.state.m[self.program.primary].dtype)
        pseg = self._place_pseg(tuple(
            jnp.full((s.nbr.shape[0], self.slots), ident)
            for s in self.pack.slices))
        self.state = self.state._replace(
            pseg=pseg, pull_dense=jnp.asarray(True))


class AlgoPool(_LanePool):
    """Fixed query slots for one ACC program over one graph."""

    def __init__(self, name: str, program: ACCProgram, g: Graph, pack: EllPack,
                 cfg: EngineConfig, slots: int, result_field: Optional[str] = None,
                 delta: Optional[EdgeDelta] = None, telemetry: bool = False):
        assert slots >= 1
        self.name = name
        self.program = program
        # served field defaults to the program's declared 'result' param
        # (kcore serves 'alive', mis 'state' — not their push-plane
        # primaries), falling back to the primary
        self.result_field = result_field or program.param(
            "result", program.primary)
        self.g = g
        self.pack = pack
        self.delta = delta
        self.cfg = cfg
        self.slots = slots
        self.lane_rid: List[Optional[int]] = [None] * slots
        # all lanes start inactive (done=True, empty frontiers)
        self.state = B.init_batch(
            program, g, cfg,
            jnp.zeros((slots,), jnp.int32),
            done=jnp.ones((slots,), bool),
            pack=pack,
            delta=delta,
            telemetry=telemetry,
        )
        # graph/pack/delta are TRACED pytree args (not closure constants), so
        # the CSR/ELL/overlay arrays are not baked into each pool's
        # executable — pools over the same graph share the device buffers,
        # and a streaming update swaps views in without a recompile.
        self._step = jax.jit(
            lambda st, g_, pack_, delta_: B.make_batched_step(
                program, g_, pack_, cfg, delta_)(st)
        )
        self._admit = jax.jit(
            lambda st, source, lane, g_, d_, deg_: _admit_lane(
                program, g_, cfg, st, source, lane, delta=d_, deg=deg_)
        )
        self._refresh_live_deg()
        self.engine_queries = 0
        self.steps = 0
        self._init_obs(telemetry)
        #: extra cache-key params; single-device results are the bitwise
        #: reference, so no distinguishing params (see serving/placement.py)
        self.cache_params: tuple = ()
        # pools whose program declares a streaming-resume contract cache its
        # `resume_fields` beyond the result plane, so dirty entries refresh
        # incrementally instead of dropping (streaming 3(e)): residual pools
        # carry (rank, resid), reelect pools (sig, pri); cascade rebuilds
        # from the served 'alive' plane alone, so nothing extra
        from repro.streaming.incremental import resume_fields

        self.cache_extra_fields = tuple(
            f for f in resume_fields(program) if f != self.result_field)

    # -- scheduling interface: free_lanes/live/admit/harvest/readmit from
    # _LanePool ---------------------------------------------------------------

    def step(self) -> None:
        if self.live():
            self.state = self._step(self.state, self.g, self.pack, self.delta)
            self.steps += 1

    # -- streaming support ---------------------------------------------------

    def set_graph(self, g: Graph, pack: EllPack,
                  delta: Optional[EdgeDelta]) -> None:
        """Swap in updated overlay views (see `_reset_masked_pull_cache`)."""
        self.g, self.pack, self.delta = g, pack, delta
        self._refresh_live_deg()
        self._reset_masked_pull_cache()


def _admit_lane(program, g, cfg, st: B.BatchState, source, lane,
                check_caps: bool = True, delta=None,
                deg=None) -> B.BatchState:
    """Write one freshly initialized query into lane `lane` (jitted).

    `g` may be a bare `B.GraphDims` (CSR-free admission, DESIGN.md §11):
    with the precomputed live-degree vector `deg`, nothing here needs the
    adjacency arrays — union volumes come from the degree sum."""
    one = B.init_batch(program, g, cfg, source[None], check_caps=check_caps,
                       delta=delta, deg=deg)
    m = {k: st.m[k].at[:, lane].set(one.m[k][:, 0]) for k in st.m}
    active = st.active.at[:, lane].set(one.active[:, 0])
    if st.hot is not None:
        st = st._replace(hot=st.hot.at[:, lane].set(True))
    st = st._replace(
        m=m,
        active=active,
        count=st.count.at[lane].set(one.count[0]),
        mode=st.mode.at[lane].set(one.mode[0]),
        it=st.it.at[lane].set(0),
        done=st.done.at[lane].set(one.done[0]),
        push_iters=st.push_iters.at[lane].set(0),
        pull_iters=st.pull_iters.at[lane].set(0),
        switches=st.switches.at[lane].set(0),
        mode_trace=st.mode_trace.at[lane].set(one.mode_trace[0]),
    )
    if cfg.masked_pull and st.pull_dense is not None:
        # the new lane has no valid partial cache yet
        st = st._replace(pull_dense=jnp.asarray(True))
    if isinstance(g, B.GraphDims):
        union_fe, overflow = B._union_volume_deg(deg, cfg, active)
    else:
        union_fe, overflow = B._union_volume(g.out, cfg, active)
    st = st._replace(union_fe=union_fe, overflow=overflow)
    return st._replace(gmode=B._consensus_mode(program, cfg, g.n_edges, st))


class GraphServer:
    """Batched multi-query serving: cache -> weighted fair queues -> pools."""

    def __init__(
        self,
        g: Graph,
        pack: EllPack,
        programs: Dict[str, ACCProgram],
        slots: "int | Dict[str, int]" = 8,
        cfg: Optional[EngineConfig] = None,
        queue_cap: int = 256,
        cache_capacity: int = 1024,
        graph_version: int = 0,
        result_fields: Optional[Dict[str, str]] = None,
        weights: Optional[Dict[str, float]] = None,
        tenant_weights: Optional[Dict[str, float]] = None,
        delta_cap: int = 0,
        mesh=None,
        placements: Optional[Dict[str, object]] = None,
        telemetry: bool = False,
        trace=None,
        obs: Optional[Observability] = None,
        cohorts: Optional[Dict[str, int]] = None,
        slo: Optional[SLOPolicy] = None,
        cohort_affinity: Optional[Dict[str, Sequence[int]]] = None,
    ):
        cfg = cfg or default_config(g)
        self.cfg = cfg
        # one switch for the whole stack (DESIGN.md §12): a trace sink or
        # an injected Observability implies enabled; disabled servers carry
        # tele=None engine states and never call device_fetch
        self.obs = obs if obs is not None else Observability(
            enabled=telemetry, trace=trace)
        telemetry = self.obs.enabled
        delta = None
        self.sg = None
        if delta_cap > 0:
            from repro.streaming import StreamingGraph

            self.sg = StreamingGraph(g, delta_cap=delta_cap)
            self.sg.version = graph_version
            g, pack, delta = self.sg.graph, self.sg.pack, self.sg.delta
        self.g = g
        self.graph_version = graph_version
        self.queue_cap = queue_cap
        self.cache = ResultCache(cache_capacity)
        self.mesh = mesh
        placements = placements or {}
        assert not placements or mesh is not None, (
            "placements require a serving mesh "
            "(serving.placement.make_serving_mesh)")
        result_fields = result_fields or {}
        # consensus cohorts (DESIGN.md §13): an algorithm's slot budget
        # splits across k leaf pools with INDEPENDENT push/pull consensus,
        # sharing one compiled step (identical shapes) — a heavy pull-mode
        # query drags only its own narrow cohort, not every lane
        self.cohorts = {
            name: int((cohorts or {}).get(name, 1)) for name in programs}
        self.pool_groups: Dict[str, List[AlgoPool]] = {}
        for name, prog in programs.items():
            s = slots[name] if isinstance(slots, dict) else slots
            k = self.cohorts[name]
            assert k >= 1, (name, k)
            if name in placements:
                from repro.serving.placement import ShardedAlgoPool

                assert k == 1, (
                    "cohorts split a single-device pool; sharded pools "
                    "isolate via Placement(consensus='local') instead")
                leaves = [ShardedAlgoPool(
                    name, prog, g, pack, cfg, s, mesh, placements[name],
                    result_field=result_fields.get(name),
                    delta=delta, telemetry=telemetry,
                )]
            else:
                assert s % k == 0, (
                    f"slots={s} for {name!r} must divide into {k} cohorts")
                leaves = []
                for i in range(k):
                    leaf = AlgoPool(
                        name if k == 1 else f"{name}#c{i}", prog, g, pack,
                        cfg, s // k,
                        result_field=result_fields.get(name),
                        delta=delta, telemetry=telemetry,
                    )
                    if i:   # same shapes + program -> share the executables
                        leaf._step = leaves[0]._step
                        leaf._admit = leaves[0]._admit
                    leaves.append(leaf)
            self.pool_groups[name] = leaves
        #: primary leaf per algorithm — the stable lookup surface
        #: (cache_params, program, result_field are identical across a
        #: group); cohorted groups' full lane sets live in `pool_groups`
        self.pools: Dict[str, AlgoPool] = {
            name: grp[0] for name, grp in self.pool_groups.items()}
        # SLO policy state (DESIGN.md §13)
        self.slo = slo
        self.degraded_pools: Dict[str, AlgoPool] = {}
        if slo is not None:
            for name in slo.degrade_algos:
                assert name in programs, name
                dprog = degraded_variant(programs[name], slo.degrade_factor)
                dp = AlgoPool(
                    f"{name}@degraded", dprog, g, pack, cfg,
                    slo.degrade_slots,
                    result_field=result_fields.get(name),
                    delta=delta, telemetry=telemetry,
                )
                # degraded results are NEVER cached (tagged pool, and
                # _harvest_pool skips the put) — the bit-exact key must not
                # serve a loosened-tolerance answer
                dp.cache_params = (("degraded", float(slo.degrade_factor)),)
                self.degraded_pools[name] = dp
        #: always-on SLO outcome counters (stats()["slo"]) — mirrored into
        #: `slo.*` registry counters when telemetry is enabled
        self.slo_counts = {f: 0 for f in SLO_FIELDS}
        self._deadline_t: Dict[int, float] = {}
        #: rid -> times preempted (policy budget) / parked-state cache key
        self._preempt_counts: Dict[int, int] = {}
        self._preempt_saved: Dict[int, tuple] = {}
        self._degraded_rids: set = set()
        # weighted fair queuing at the admission edge: per-(tenant, algo)
        # queues, each owning (algo share) x (tenant share) of the budget
        weights = weights or {}
        self.weights = {name: float(weights.get(name, 1.0)) for name in programs}
        total_w = sum(self.weights.values())
        self.queue_quota = {
            name: max(1, int(queue_cap * w / total_w))
            for name, w in self.weights.items()
        }
        self.tenants = (
            {t: float(w) for t, w in tenant_weights.items()}
            if tenant_weights else {"default": 1.0}
        )
        # `or 1.0`: all-zero declared weights still yield the max(1, ...)
        # floor share below instead of a ZeroDivisionError
        total_t = sum(self.tenants.values()) or 1.0
        self.tenant_quota = {
            (name, t): max(1, int(self.queue_quota[name] * tw / total_t))
            for name in programs for t, tw in self.tenants.items()
        }
        # tenant -> cohort affinity (DESIGN.md §13): a listed tenant only
        # admits into leaf ordinals `i % k` of each algorithm's k-leaf
        # cohort group; unlisted tenants land anywhere. Confining a heavy
        # best-effort tenant to one cohort is what lets the step cadence
        # (SLOPolicy.cohort_burst / best_effort_stride) starve only that
        # leaf instead of every lane in the pool.
        self.cohort_affinity: Dict[str, Tuple[int, ...]] = {}
        for t, idxs in (cohort_affinity or {}).items():
            assert t in self.tenants, (
                f"cohort_affinity tenant {t!r} not declared "
                f"(declared: {sorted(self.tenants)})")
            norm = tuple(sorted({int(i) for i in idxs}))
            assert norm, f"cohort_affinity for {t!r} must list >= 1 cohort"
            self.cohort_affinity[t] = norm
        #: pump round counter — the clock `best_effort_stride` gates on
        self._round = 0
        self.queues: Dict[str, Dict[str, deque]] = {
            name: {t: deque() for t in self.tenants} for name in programs
        }
        #: per-algo rotation pointer into the tenant list — dealing resumes
        #: AFTER the last-served tenant instead of restarting at the first,
        #: so a tenant whose weight rounds to the minimum share still gets a
        #: lane every rotation (starvation fix, tests/test_serving.py)
        self._rr: Dict[str, int] = {name: 0 for name in programs}
        self._next_rid = 0
        self._inflight_sources: Dict[int, int] = {}
        self._inflight_tenants: Dict[int, str] = {}
        #: rid -> submit wall clock, kept only while the health monitor is
        #: on — feeds end-to-end latency into its P² estimators
        self._submit_t: Dict[int, float] = {}
        self.completions: List[Completion] = []
        self.rejected = 0
        self.update_log: List[dict] = []

    # -- request side --------------------------------------------------------

    def submit(self, algo: str, source: int, strict: bool = False,
               tenant: str = "default",
               deadline_ms: Optional[float] = None) -> Optional[int]:
        """Enqueue a query; returns its rid, or None when the (tenant, algo)
        queue share is full (backpressure — caller sheds or retries;
        `strict=True` raises). One tenant flooding one algorithm exhausts
        only its own share of that algorithm's budget; every other
        (tenant, algo) share is untouched.

        `deadline_ms` attaches a latency SLO: the completion (and span) is
        flagged `deadline_missed` if it finishes late, and an active
        `SLOPolicy` may drop/degrade/preempt around it (DESIGN.md §13). A
        deadline already expired at submit completes immediately as
        `dropped` under a drop policy (the rid is still returned — the
        outcome is in the completion)."""
        if algo not in self.pools:
            raise KeyError(f"no pool for algorithm {algo!r}")
        if tenant not in self.tenants:
            raise KeyError(
                f"unknown tenant {tenant!r} (declared: {sorted(self.tenants)})")
        now = time.monotonic()
        deadline_t = (None if deadline_ms is None
                      else now + float(deadline_ms) / 1e3)
        rid = self._next_rid
        key = make_key(self.graph_version, algo, source,
                       self.pools[algo].cache_params)
        hit = self.cache.get(key)
        reg = self.obs.registry
        reg.counter("requests_total").inc()
        if hit is not None:
            self._next_rid += 1
            missed = deadline_t is not None and now > deadline_t
            if missed:
                self._count_slo("deadline_missed")
            reg.counter("cache_hits_total").inc()
            self._rec("cache_hit", rid=rid, algo=algo, source=int(source))
            self.obs.health.on_complete(0.0, deadline_missed=missed)
            tr = self.obs.tracer
            tr.begin(rid, algo, int(source), tenant, self.graph_version)
            tr.complete(rid, from_cache=True, iterations=0,
                        slo=self._span_slo(deadline_t, missed=missed))
            self.completions.append(Completion(
                rid=rid, algo=algo, source=int(source),
                result=served_result(hit),
                iterations=0, from_cache=True,
                graph_version=self.graph_version, tenant=tenant,
                deadline_missed=missed,
            ))
            return rid
        if (self.slo is not None and self.slo.drop_expired
                and deadline_t is not None and now >= deadline_t):
            self._next_rid += 1
            if self.obs.health.enabled:
                self._submit_t[rid] = now
            self.obs.tracer.begin(rid, algo, int(source), tenant,
                                  self.graph_version)
            self._drop_request(Request(
                rid=rid, algo=algo, source=int(source), tenant=tenant,
                deadline_t=deadline_t))
            return rid
        if len(self.queues[algo][tenant]) >= self.tenant_quota[(algo, tenant)]:
            self.rejected += 1
            reg.counter("rejected_total").inc()
            if strict:
                raise QueueFull(
                    f"queue for tenant {tenant!r} of {algo!r} at its share "
                    f"{self.tenant_quota[(algo, tenant)]} of capacity "
                    f"{self.queue_cap}")
            return None
        self._next_rid += 1
        if deadline_t is not None:
            self._deadline_t[rid] = deadline_t
        if self.obs.health.enabled:
            self._submit_t[rid] = now
        self.obs.tracer.begin(rid, algo, int(source), tenant,
                              self.graph_version)
        self.queues[algo][tenant].append(
            Request(rid=rid, algo=algo, source=int(source), tenant=tenant,
                    deadline_t=deadline_t))
        return rid

    # -- SLO bookkeeping -----------------------------------------------------

    def _count_slo(self, field: str) -> None:
        self.slo_counts[field] += 1
        self.obs.registry.counter(f"slo.{field}").inc()

    # -- flight recorder / health (DESIGN.md §14) ----------------------------

    def _rec(self, kind: str, **payload) -> None:
        """Record one flight-recorder event (free when unarmed; host-only
        when armed — never reads device state)."""
        r = self.obs.flight
        if r is not None:
            r.record(kind, **payload)

    def _health_complete(self, rid: int, now: float, *, missed: bool,
                         dropped: bool = False) -> None:
        """Feed one finished request into the health monitor's latency
        estimators and windowed gauges."""
        t0 = self._submit_t.pop(rid, None)
        self.obs.health.on_complete(
            (now - t0) if t0 is not None else 0.0,
            deadline_missed=missed, dropped=dropped)

    def dump_flight_record(self, path: str) -> int:
        """Post-mortem export: write the flight ring to `path` as JSONL
        (scripts/trace_schema.py --flight validates it), after appending one
        `imbalance` summary event per pool group — the latest per-shard
        scan-volume plane and its skew ratio, so a dump carries the workload
        profile alongside the event timeline. Returns events written; an
        unarmed server writes an empty file (callers may ship the path
        unconditionally)."""
        rec = self.obs.flight
        if rec is None:
            open(path, "w").close()
            return 0
        for name, grp in self.pool_groups.items():
            plane = self._group_plane(grp)
            if plane.size:
                rec.record("imbalance", pool=name,
                           shard_edges=[int(x) for x in plane],
                           skew=round(skew_ratio(plane), 4))
        return rec.dump(path)

    @staticmethod
    def _group_plane(grp: List["AlgoPool"]) -> np.ndarray:
        """A pool group's per-shard scan plane: the latest cumulative plane
        of each cohort leaf, concatenated (sharded groups have one leaf
        whose plane is the mesh axis; cohort groups expose per-cohort scan
        volumes). Empty when telemetry is off or nothing has stepped."""
        parts = [np.asarray(q.iter_log[-1]["shard_edges"], np.int64)
                 for q in grp if getattr(q, "iter_log", None)]
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.int64))

    @staticmethod
    def _span_slo(deadline_t: Optional[float], *, missed: bool = False,
                  dropped: bool = False, degraded: bool = False,
                  preempted: bool = False) -> Optional[dict]:
        """Span `slo` payload; None when the request had no deadline and no
        policy action touched it (keeps pre-SLO traces byte-stable)."""
        if deadline_t is None and not (missed or dropped or degraded
                                       or preempted):
            return None
        return {
            "deadline_s": None if deadline_t is None else round(
                float(deadline_t), 9),
            "deadline_missed": bool(missed),
            "dropped": bool(dropped),
            "degraded": bool(degraded),
            "preempted": bool(preempted),
        }

    def _drop_request(self, req: Request) -> None:
        """Complete a queued (or just-submitted, or just-evicted) request as
        DROPPED: no result, counted, span-closed. Drops imply a missed
        deadline — the policy only sheds work that cannot finish in time."""
        rid = req.rid
        self._count_slo("dropped")
        self._count_slo("deadline_missed")
        self._rec("drop", rid=rid, algo=req.algo, tenant=req.tenant)
        self._health_complete(rid, time.monotonic(), missed=True,
                              dropped=True)
        self._deadline_t.pop(rid, None)
        was_preempted = rid in self._preempt_counts
        self._preempt_counts.pop(rid, None)
        key = self._preempt_saved.pop(rid, None)
        if key is not None:
            self.cache.pop(key)   # parked partial state dies with the query
        self.obs.tracer.complete(
            rid, from_cache=False, iterations=0,
            slo=self._span_slo(req.deadline_t, missed=True, dropped=True,
                               preempted=was_preempted))
        self.completions.append(Completion(
            rid=rid, algo=req.algo, source=req.source, result=None,
            iterations=0, from_cache=False,
            graph_version=self.graph_version, tenant=req.tenant,
            deadline_missed=True, dropped=True, preempted=was_preempted,
        ))

    # -- serving loop --------------------------------------------------------

    def _queued(self) -> int:
        return sum(len(q) for qs in self.queues.values() for q in qs.values())

    def _leaves(self):
        """Every concrete lane pool the scheduling loop drives: each
        algorithm's cohort leaves, then the degraded shadow pools.
        Yields (algo, pool, degraded)."""
        for name, grp in self.pool_groups.items():
            for p in grp:
                yield name, p, False
        for name, p in self.degraded_pools.items():
            yield name, p, True

    def pump(self) -> List[Completion]:
        """One scheduling round per algorithm: SLO admission scan (drop
        expired/hopeless queued queries, maybe preempt a long-resident lane
        for deadline-critical queued work), deal free lanes — interleaved
        across cohort leaves, rotation-fair across tenants — then route
        overflow to the degraded shadow pool under queue pressure; one
        batched step per live leaf, harvest converged lanes. Returns this
        round's completions (drops included). Fairness across algorithms
        comes from the weighted queue shares enforced at submit."""
        n0 = len(self.completions)
        now = time.monotonic()
        for name, grp in self.pool_groups.items():
            if self.slo is not None:
                self._slo_admission_scan(name, grp, now)
                self._maybe_preempt(name, grp, now)
            lanes = self._deal_lanes(grp)
            self._admit_from_queues(name, lanes, degraded=False)
            dp = self.degraded_pools.get(name)
            if dp is not None and self._pressure(name, now):
                dlanes = deque((0, dp, l) for l in dp.free_lanes())
                self._admit_from_queues(name, dlanes, degraded=True)

        new: List[Completion] = []
        self._round += 1
        for name, grp in self.pool_groups.items():
            for ordinal, pool in enumerate(grp):
                self._step_leaf(pool, self._leaf_cadence(name, pool, ordinal))
                new.extend(self._harvest_pool(name, pool, degraded=False))
        for name, dp in self.degraded_pools.items():
            self._step_leaf(dp, 1)
            new.extend(self._harvest_pool(name, dp, degraded=True))
        if self.obs.enabled:
            qd = self._queued()
            self.obs.registry.gauge("queued").set(qd)
            self.obs.health.on_queue_depth(qd)
        self.completions.extend(new)
        return self.completions[n0:]

    def _step_leaf(self, pool: AlgoPool, k: int) -> None:
        """Advance one leaf pool up to `k` batched steps this round (0 = a
        stride-skipped best-effort cohort; >1 = a deadline burst), stopping
        early once nothing is live."""
        for _ in range(k):
            if not pool.live():
                break
            pool.step()
            if self.obs.enabled:
                entry = pool.log_iter()
                reg = self.obs.registry
                reg.histogram(f"{pool.name}.union_fe",
                              default_count_buckets()).observe(
                    entry["union_fe"])
                reg.gauge(f"{pool.name}.live_lanes").set(entry["live"])
                # workload-imbalance profile (DESIGN.md §14): per-lane
                # frontier-size distribution + per-shard scan skew, both
                # read from the sample log_iter already fetched
                fhist = reg.histogram(f"{pool.name}.frontier",
                                      default_count_buckets())
                for c in entry["counts"]:
                    if c > 0:
                        fhist.observe(int(c))
                if len(entry["shard_edges"]):
                    reg.gauge(f"{pool.name}.shard_skew").set(
                        skew_ratio(entry["shard_edges"]))
                audit = pool.audit_log[-1] if pool.audit_log else None
                if audit is not None and self.obs.flight is not None:
                    if audit["switched"]:
                        self._rec("mode_switch", pool=pool.name,
                                  step=audit["step"], mode=audit["mode"],
                                  union_fe=audit["union_fe"])
                    if audit["compact_dense_d"]:
                        self._rec("compact_overflow", pool=pool.name,
                                  step=audit["step"],
                                  n=audit["compact_dense_d"])

    def _leaf_cadence(self, name: str, pool: AlgoPool, ordinal: int) -> int:
        """Steps this cohort leaf gets this round (DESIGN.md §13). The
        measured cost model behind the knobs: a batched step prices by
        ALLOCATED lanes Q (plus an m-bound constant), not by live content,
        and the host backend pumps leaves sequentially with no dispatch
        overlap — so a leaf's only isolation lever is step frequency.
        Deadline-bearing leaves may burst `cohort_burst` steps per round;
        best-effort-only leaves step every `best_effort_stride`-th round.
        Defaults (1/1) reproduce the flat one-step-per-leaf schedule."""
        pol = self.slo
        if pol is None or len(self.pool_groups[name]) <= 1:
            return 1
        burst = max(1, pol.cohort_burst)
        stride = max(1, pol.best_effort_stride)
        if burst == 1 and stride == 1:
            return 1
        if any(rid is not None and rid in self._deadline_t
               for rid in pool.lane_rid):
            return burst
        return 1 if (self._round + ordinal) % stride == 0 else 0

    def _deal_lanes(self, grp: List[AlgoPool]) -> deque:
        """Free lanes of a cohort group as (ordinal, pool, lane) triples,
        interleaved round-robin across leaves so admissions spread load (and
        pull-mode risk) instead of filling one cohort first."""
        per = [deque(p.free_lanes()) for p in grp]
        lanes: deque = deque()
        while any(per):
            for i, (p, q) in enumerate(zip(grp, per)):
                if q:
                    lanes.append((i, p, q.popleft()))
        return lanes

    def _take_lane(self, lanes: deque, tenant: str, k: int,
                   degraded: bool) -> Optional[tuple]:
        """Pop the first dealt lane this tenant may use: any lane when the
        tenant has no cohort affinity (or for the degraded shadow pool —
        a single leaf, no cohorts to pin), else the first whose leaf
        ordinal falls in the tenant's allowed set mod the group size.
        Returns None when no allowed lane remains (the tenant waits)."""
        allowed = None if degraded else self.cohort_affinity.get(tenant)
        if allowed is None:
            return lanes.popleft()
        allow = {i % k for i in allowed}
        for idx, (ordinal, _p, _l) in enumerate(lanes):
            if ordinal in allow:
                item = lanes[idx]
                del lanes[idx]
                return item
        return None

    def _admit_from_queues(self, name: str, lanes: deque,
                           degraded: bool) -> None:
        """Deal `lanes` to this algorithm's tenant queues, resuming the
        rotation AFTER the last-served tenant (`self._rr`): a minimum-share
        tenant is guaranteed a lane every full rotation even when lanes free
        one per pump — restarting at the first tenant each sweep starved
        everyone behind a persistently-backlogged tenant. Affinity-pinned
        tenants only take lanes in their allowed cohorts; a full sweep that
        places nothing (every backlogged tenant pinned away from every
        remaining lane) ends the deal."""
        qs = self.queues[name]
        tl = list(self.tenants)
        k = len(self.pool_groups[name]) if name in self.pool_groups else 1
        while lanes and any(qs.values()):
            placed = False
            for j in range(len(tl)):
                t = tl[(self._rr[name] + j) % len(tl)]
                if not qs[t]:
                    continue
                dealt = self._take_lane(lanes, t, k, degraded)
                if dealt is None:
                    continue
                self._rr[name] = (self._rr[name] + j + 1) % len(tl)
                req = qs[t].popleft()
                _ordinal, pool, lane = dealt
                self._admit_one(pool, lane, req, degraded)
                placed = True
                break
            if not placed:
                break

    def _admit_one(self, pool: AlgoPool, lane: int, req: Request,
                   degraded: bool) -> None:
        rid = req.rid
        resumed = False
        if not degraded and rid in self._preempt_saved:
            key = self._preempt_saved.pop(rid)
            entry = self.cache.pop(key)
            if entry is not None:
                # resume the fixpoint from the parked partial state instead
                # of restarting (preemption contract, DESIGN.md §13); a
                # capacity-evicted entry falls back to a fresh admit
                pool.admit_resume(lane, rid, {
                    "planes": entry.extras["planes"],
                    "it": entry.extras["it"],
                    "trace": entry.extras["trace"],
                })
                resumed = True
        if not resumed:
            pool.admit(lane, rid, req.source)
        self._inflight_sources[rid] = req.source
        self._inflight_tenants[rid] = req.tenant
        self._rec("resume" if resumed else "admit", rid=rid,
                  pool=pool.name, lane=lane, algo=req.algo)
        if degraded:
            self._degraded_rids.add(rid)
            self._count_slo("degraded")
            self._rec("degrade", rid=rid, pool=pool.name)
        self.obs.tracer.mark(rid, "admit")

    def _group_ewma(self, grp: List[AlgoPool]) -> Optional[float]:
        seen = [p.ewma_resident_s for p in grp
                if p.ewma_resident_s is not None]
        return sum(seen) / len(seen) if seen else None

    def _slo_admission_scan(self, name: str, grp: List[AlgoPool],
                            now: float) -> None:
        """Shed queued queries that cannot make their deadline: already
        expired (`drop_expired`), or hopeless — even admitted RIGHT NOW the
        EWMA service-time estimate overshoots the deadline by the policy
        margin."""
        pol = self.slo
        est = self._group_ewma(grp)
        for t, q in self.queues[name].items():
            kept: deque = deque()
            while q:
                req = q.popleft()
                dt = req.deadline_t
                drop = False
                if dt is not None:
                    if pol.drop_expired and now >= dt:
                        drop = True
                    elif (pol.hopeless_margin > 0 and est is not None
                          and now + pol.hopeless_margin * est > dt):
                        drop = True
                if drop:
                    self._drop_request(req)
                else:
                    kept.append(req)
            self.queues[name][t] = kept

    def _pressure(self, name: str, now: float) -> bool:
        """Queue pressure that justifies degraded-pool routing: the
        algorithm's backlog at/above the policy depth, or any queued
        deadline's slack under the policy floor."""
        pol = self.slo
        queued = sum(len(q) for q in self.queues[name].values())
        if queued == 0:
            return False
        if queued >= pol.degrade_queue_depth:
            return True
        slacks = [r.deadline_t - now for q in self.queues[name].values()
                  for r in q if r.deadline_t is not None]
        return bool(slacks) and min(slacks) < pol.degrade_slack_s

    def _maybe_preempt(self, name: str, grp: List[AlgoPool],
                       now: float) -> None:
        """Evict (at most) one long-resident lane per algorithm per pump
        when the group is lane-starved and queued deadline-critical work
        would otherwise miss: the victim's partial state parks in the cache
        and the query re-queues at the FRONT of its tenant queue (it has
        already waited once). Residual-push pools only — their mid-run state
        is resumable. A victim already past its own deadline is dropped
        outright (eviction)."""
        pol = self.slo
        if not pol.preempt:
            return
        if grp[0].program.param("kind") != "residual":
            return
        if any(p.free_lanes() for p in grp):
            return
        slacks = [r.deadline_t - now for q in self.queues[name].values()
                  for r in q if r.deadline_t is not None]
        if not slacks:
            return
        est = self._group_ewma(grp)
        trigger = max(pol.preempt_slack_s,
                      pol.preempt_slack_factor * (est or 0.0))
        if min(slacks) >= trigger:
            return
        victim = None   # (resident_s, pool, lane, rid)
        for p in grp:
            for lane, rid in enumerate(p.lane_rid):
                if rid is None:
                    continue
                resident = now - p.lane_admit_t[lane]
                if resident < pol.preempt_min_resident_s:
                    continue
                if self._preempt_counts.get(rid, 0) >= pol.max_preempts:
                    continue
                if victim is None or resident > victim[0]:
                    victim = (resident, p, lane, rid)
        if victim is None:
            return
        _resident, pool, lane, rid = victim
        saved = pool.preempt(lane)
        source = self._inflight_sources.pop(rid)
        tenant = self._inflight_tenants.pop(rid, "default")
        self._preempt_counts[rid] = self._preempt_counts.get(rid, 0) + 1
        self._count_slo("preempted")
        self._rec("preempt", rid=rid, pool=pool.name, lane=lane,
                  resident_s=round(_resident, 6))
        self.obs.tracer.mark(rid, "preempt")
        dt = self._deadline_t.get(rid)
        req = Request(rid=rid, algo=name, source=source, tenant=tenant,
                      deadline_t=dt)
        if dt is not None and now >= dt and pol.drop_expired:
            self._drop_request(req)
            return
        key = make_key(self.graph_version, name, source,
                       (("partial", rid),))
        self.cache.put(key, CachedEntry(
            saved["planes"][pool.result_field][:-1],
            {"planes": saved["planes"], "it": saved["it"],
             "trace": saved["trace"]},
        ))
        if key in self.cache:   # capacity 0 stores nothing -> fresh restart
            self._preempt_saved[rid] = key
        self.queues[name][tenant].appendleft(req)

    def _harvest_pool(self, name: str, pool: AlgoPool,
                      degraded: bool = False) -> List[Completion]:
        out = []
        harvested = pool.harvest()
        mode_rows = None
        if harvested and self.obs.enabled:
            # per-request per-iteration modes come from the existing
            # mode-trace machinery: ONE matrix transfer per harvest that
            # actually yields lanes (never per lane)
            mode_rows = device_fetch(pool.state.mode_trace)
        now = time.monotonic()
        for lane, rid, result, iters, extras in harvested:
            pool.observe_resident(now - pool.lane_admit_t[lane])
            dt = self._deadline_t.pop(rid, None)
            missed = dt is not None and now > dt
            if missed:
                self._count_slo("deadline_missed")
            self._rec("harvest", rid=rid, pool=pool.name, lane=lane,
                      iters=iters)
            self._health_complete(rid, now, missed=missed)
            was_preempted = rid in self._preempt_counts
            self._preempt_counts.pop(rid, None)
            self._degraded_rids.discard(rid)
            comp = Completion(
                rid=rid, algo=name, source=self._source_of(rid, name, result),
                result=result, iterations=iters, from_cache=False,
                graph_version=self.graph_version,
                tenant=self._inflight_tenants.pop(rid, "default"),
                deadline_missed=missed, degraded=degraded,
                preempted=was_preempted,
            )
            if not degraded:
                # degraded answers never cache-fill: the bit-exact key must
                # keep serving full-tolerance results only
                self.cache.put(
                    make_key(self.graph_version, comp.algo, comp.source,
                             pool.cache_params),
                    CachedEntry(comp.result, extras) if extras
                    else comp.result,
                )
            if self.obs.enabled:
                self._complete_span(
                    name, pool, lane, rid, iters, mode_rows,
                    slo=self._span_slo(dt, missed=missed, degraded=degraded,
                                       preempted=was_preempted))
            out.append(comp)
        return out

    def _complete_span(self, name: str, pool: AlgoPool, lane: int, rid: int,
                       iters: int, mode_rows,
                       slo: Optional[dict] = None) -> None:
        """Close an engine-served request's span: assemble its per-iteration
        list from the lane's mode-trace row + the pool iteration log's
        per-lane frontier counts / union volumes, observe the lifecycle
        latency histograms. A preempt-resumed lane's pre-preemption
        iterations predate this pool residency's log, so they pad as None
        gaps (`lane_it_base`), keeping mode-trace alignment."""
        tr = self.obs.tracer
        tr.mark(rid, "harvest")
        admit_step = pool.lane_admit_step[lane]
        it0 = pool.lane_it_base[lane]
        counts: List[Optional[int]] = [None] * it0
        unions: List[Optional[int]] = [None] * it0
        for e in pool.iter_log:
            i = e["step"] - admit_step - 1     # iters run THIS residency
            if i < 0:
                continue
            while len(counts) < it0 + i:       # bounded log dropped samples:
                counts.append(None)            # None gaps keep alignment
                unions.append(None)
            counts.append(int(e["counts"][lane]))
            unions.append(int(e["union_fe"]))
        span = tr.complete(rid, from_cache=False, iterations=iters,
                           iters=iters_from_trace(mode_rows[lane], counts,
                                                  unions),
                           graph_version=self.graph_version, slo=slo)
        if span is None:
            return
        d = span.durations()
        reg = self.obs.registry
        lat = default_latency_buckets()
        # cohort leaves aggregate under the ALGORITHM name (capacity split is
        # an implementation detail); the degraded shadow pool keeps its own
        # series — its latencies are not comparable to full-tolerance serving
        hname = pool.name if slo is not None and slo["degraded"] else name
        reg.histogram(f"{hname}.latency_total_s", lat).observe(d["total_s"])
        reg.histogram(f"{hname}.queue_wait_s", lat).observe(d["queue_wait_s"])
        reg.histogram(f"{hname}.resident_s", lat).observe(d["resident_s"])
        reg.histogram(f"{hname}.iterations",
                      default_count_buckets()).observe(iters)
        reg.counter("completions_engine_total").inc()

    def _source_of(self, rid: int, algo: str, result) -> int:
        return self._inflight_sources.pop(rid)

    def drain(self, max_rounds: int = 100000) -> List[Completion]:
        """Pump until the queues and every pool are empty; returns ALL
        completions accumulated so far (cache hits included)."""
        rounds = 0
        while self._queued() or any(p.live() for _n, p, _d in self._leaves()):
            self.pump()
            rounds += 1
            if rounds >= max_rounds:
                # leave a post-mortem timeline before dying: the wedge is
                # exactly what the flight recorder exists for
                self._rec("drain_stuck", rounds=rounds,
                          queued=self._queued())
                if self.obs.flight is not None:
                    path = "/tmp/repro_flight_drain_stuck.jsonl"
                    n = self.dump_flight_record(path)
                    raise RuntimeError(
                        f"drain did not converge "
                        f"(flight record: {n} events -> {path})")
                raise RuntimeError("drain did not converge")
        return self.completions

    # -- streaming updates ---------------------------------------------------

    def apply_updates(self, inserts=(), deletes=(), refresh: str = "incremental") -> dict:
        """Absorb one edge-update batch into the served graph (DESIGN.md §8).

        1. Harvest finished lanes under the OLD version (their results are
           valid for it and cache-fill there).
        2. Apply the batch to the StreamingGraph; swap the overlaid views
           into every pool (traced args — no recompile off the rebuild path).
        3. Selectively invalidate the LRU: entries whose source cannot reach
           a touched endpoint are RE-KEYED to the new version; dirty entries
           of monotone programs are refreshed incrementally from their cached
           fixpoint when `refresh='incremental'`, else dropped.
        4. Restart dirtied in-flight lanes from scratch on the new graph
           (clean in-flight lanes continue — their trajectories cannot see
           the updated edges).

        Returns a stats dict (also appended to `self.update_log`).
        """
        assert self.sg is not None, "GraphServer built without delta_cap"
        assert refresh in ("incremental", "drop")
        # (1) don't let finished old-graph results leak into the new version
        for name, pool, degraded in self._leaves():
            self.completions.extend(
                self._harvest_pool(name, pool, degraded=degraded))

        old_version = self.graph_version
        report = self.sg.apply(inserts, deletes)
        self.graph_version = report.version
        self.g = self.sg.graph
        for _name, pool, _degraded in self._leaves():
            pool.set_graph(self.sg.graph, self.sg.pack, self.sg.delta)
        # parked preempted state is version-bound: the saved residuals are
        # only Maiter-correctable while resident in a pool, so a version
        # bump invalidates the parked copies and those queries restart
        for rid, key in list(self._preempt_saved.items()):
            self.cache.pop(key)
            del self._preempt_saved[rid]

        # (3) selective cache invalidation / refresh. dirty_src gating is
        # only meaningful for SOURCE-parameterized programs (the cached
        # result is a function of one source's reachable region); a
        # source-free program's result (wcc/kcore/mis/global pagerank)
        # depends on the whole graph, so any non-empty batch dirties it.
        changed = (report.n_inserted + report.n_deleted) > 0
        retained = dropped = refreshed = 0
        dirty_entries: Dict[str, list] = {name: [] for name in self.pools}
        for key, value in self.cache.take_version(old_version):
            _v, algo, source, params = key
            source_gated = (algo in self.pools
                            and B._accepts_source(self.pools[algo].program))
            clean = ((not report.dirty_src[source]) if source_gated
                     else not changed)
            if algo in self.pools and clean:
                self.cache.put(
                    make_key(self.graph_version, algo, source, params), value)
                retained += 1
            elif (algo in self.pools
                  and params == self.pools[algo].cache_params):
                # entries matching their pool's current cache tag (() for
                # bit-exact pools, the placement tag for edge-sharded sum
                # pools) are refresh candidates — re-keyed under the same tag
                dirty_entries[algo].append((source, value))
            else:
                dropped += 1
        if refresh == "incremental":
            refreshed, dropped2 = self._refresh_cached(dirty_entries)
            dropped += dropped2
        else:
            dropped += sum(len(v) for v in dirty_entries.values())
        self.cache.note_invalidated(dropped)

        # (4) dirtied in-flight queries: residual-push pools RESUME every
        # live lane from Maiter-corrected residuals (clean lanes' corrections
        # are identically zero — they continue bitwise unchanged); everything
        # else restarts its dirty lanes from scratch on the new graph
        from repro.streaming.incremental import is_residual

        re_enqueued_rids = []
        resumed_inflight = 0
        for _name, pool, _degraded in self._leaves():
            if is_residual(pool.program):
                if pool.live():
                    resumed_inflight += pool.resume_residual(self.sg, report)
                continue
            source_gated = B._accepts_source(pool.program)
            for lane, rid in enumerate(pool.lane_rid):
                if rid is None:
                    continue
                source = self._inflight_sources[rid]
                # source-free lanes see the whole graph — any non-empty
                # batch dirties them (mid-run non-monotone state is not a
                # fixpoint, so contract resumes don't apply; restart)
                if report.dirty_src[source] if source_gated else changed:
                    pool.readmit(lane, source)
                    re_enqueued_rids.append(rid)

        stats = {
            "version": self.graph_version,
            "inserted": report.n_inserted,
            "deleted": report.n_deleted,
            "ignored": report.n_ignored,
            "rebuild": report.rebuild,
            "cache_retained": retained,
            "cache_refreshed": refreshed,
            "cache_dropped": dropped,
            "reenqueued_inflight": len(re_enqueued_rids),
            "reenqueued_rids": re_enqueued_rids,
            "resumed_inflight": resumed_inflight,
            # touched-delta slice shipping (DESIGN.md §11): what each
            # sharded pool's view swap actually moved to the mesh
            "shipped": {
                p.name: dict(p.engine.last_ship)
                for _n, p, _d in self._leaves() if hasattr(p, "engine")
            },
        }
        self.update_log.append(stats)
        self._rec("update_swap", version=self.graph_version,
                  inserted=report.n_inserted, deleted=report.n_deleted,
                  rebuild=report.rebuild,
                  resumed=resumed_inflight, reenqueued=len(re_enqueued_rids))
        return stats

    def _refresh_cached(self, dirty_entries: Dict[str, list],
                        chunk: int = 64) -> tuple:
        """Incrementally recompute dirty cached fixpoints instead of
        dropping them, per program regime:

          * monotone single-field programs (BFS/SSSP/WCC): the cached (n,)
            primary IS the full metadata, so the previous fixpoint is
            reconstructible and resumes bit-identically;
          * residual-push programs (`ppr_delta`, `pagerank_delta`): cached
            entries carry the (estimate, residual) split (`CachedEntry`),
            so the refresh Maiter-corrects the residuals and RESUMES the
            fixpoint via `reseed_from_residuals` — a bare rank would not be
            resumable and used to drop (ROADMAP streaming 3(e));
          * declared-contract programs (params incremental='cascade' |
            'reelect'): the cached result plane plus the declared
            `resume_fields` extras reconstruct the previous fixpoint, and
            `incremental_batch` resumes it (k-core deletion cascade, MIS
            region re-election) — falling back internally to full recompute
            when the contract cannot cover the batch (cascade + inserts);
          * everything else is dropped (recompute-on-demand IS the full
            fallback, paid lazily only for entries actually re-requested).

        Refreshed entries re-key under their pool's cache tag (the
        edge-sharded placement tag included): the refresh itself runs on
        the single-device incremental engine, which is fine — refreshed
        fixpoints are tol-accurate by contract, and the tag's only promise
        is that the bit-exact () key never serves a foreign bit pattern.
        """
        from repro.streaming import incremental_batch, is_monotone
        from repro.streaming.incremental import (
            incremental_contract,
            is_residual,
            resume_fields,
        )

        refreshed = dropped = 0
        n = self.sg.n
        for algo, entries in dirty_entries.items():
            if not entries:
                continue
            pool = self.pools[algo]
            program = pool.program
            est_f = program.param("estimate", "rank")
            if is_residual(program) and pool.result_field == est_f:
                res_f = program.param("residual", "resid")
                # only wrapped entries carry the resumable residual plane
                ok = [(s, v) for s, v in entries
                      if isinstance(v, CachedEntry) and res_f in v.extras]
                dropped += len(entries) - len(ok)
                for i in range(0, len(ok), chunk):
                    part = ok[i:i + chunk]
                    sources = np.asarray([s for s, _v in part], np.int64)
                    zrow = np.zeros((1,), np.float32)
                    prev_m = {
                        est_f: np.stack(
                            [np.concatenate([v.result, zrow])
                             for _s, v in part], axis=1),
                        res_f: np.stack(
                            [np.concatenate([v.extras[res_f], zrow])
                             for _s, v in part], axis=1),
                    }
                    m, _info = incremental_batch(
                        program, self.sg, self.cfg, sources, prev_m)
                    rank = np.asarray(m[est_f])
                    resid = np.asarray(m[res_f])
                    for j, s in enumerate(sources):
                        self.cache.put(
                            make_key(self.graph_version, algo, int(s),
                                     pool.cache_params),
                            CachedEntry(rank[:n, j],
                                        {res_f: resid[:n, j]}))
                    refreshed += len(part)
                continue
            contract = incremental_contract(program)
            if (contract in ("cascade", "reelect")
                    and pool.result_field == program.param(
                        "result", program.primary)):
                needed = [f for f in resume_fields(program)
                          if f != pool.result_field]
                ok = [(s, v) for s, v in entries
                      if not needed
                      or (isinstance(v, CachedEntry)
                          and all(f in v.extras for f in needed))]
                dropped += len(entries) - len(ok)
                zrow = np.zeros((1,), np.float32)

                def _col(v, f):
                    if f == pool.result_field:
                        arr = v.result if isinstance(v, CachedEntry) else v
                    else:
                        arr = v.extras[f]
                    return np.concatenate([arr, zrow])

                fields = sorted({pool.result_field, *needed})
                for i in range(0, len(ok), chunk):
                    part = ok[i:i + chunk]
                    sources = np.asarray([s for s, _v in part], np.int64)
                    prev_m = {f: np.stack([_col(v, f) for _s, v in part],
                                          axis=1) for f in fields}
                    m, _info = incremental_batch(
                        program, self.sg, self.cfg, sources, prev_m)
                    res = np.asarray(m[pool.result_field])
                    ext = {f: np.asarray(m[f]) for f in needed}
                    for j, s in enumerate(sources):
                        value = (CachedEntry(
                            res[:n, j], {f: ext[f][:n, j] for f in needed})
                            if needed else res[:n, j])
                        self.cache.put(
                            make_key(self.graph_version, algo, int(s),
                                     pool.cache_params), value)
                    refreshed += len(part)
                continue
            reconstructible = (
                is_monotone(program)
                and set(pool.state.m.keys()) == {program.primary}
                and pool.result_field == program.primary
            )
            if not reconstructible:
                dropped += len(entries)
                continue
            ident = np.asarray(program.combiner.identity(jnp.float32))
            for i in range(0, len(entries), chunk):
                part = entries[i:i + chunk]
                sources = np.asarray([s for s, _v in part], np.int64)
                cols = [np.concatenate([v, ident[None]]) for _s, v in part]
                prev_m = {program.primary: np.stack(cols, axis=1)}
                m, _info = incremental_batch(
                    program, self.sg, self.cfg, sources, prev_m)
                res = np.asarray(m[program.primary])
                for j, s in enumerate(sources):
                    self.cache.put(
                        make_key(self.graph_version, algo, int(s),
                                 pool.cache_params),
                        res[:n, j])
                refreshed += len(part)
        return refreshed, dropped

    def stats(self) -> dict:
        """The serving stack's ONE stats surface (DESIGN.md §12) — every
        scattered counter unified behind a documented schema:

          completed / queued / rejected / inflight   request-side totals
          cache          ResultCache.stats(): size, capacity, hits, misses,
                         hit_rate, evictions, invalidations
          graph_version  version served right now
          graph          {n_nodes, n_edges, streaming} — `streaming` is
                         StreamingGraph.stats() (delta overlay occupancy
                         `delta_fill`, rebuilds) or None for static servers
          updates        count of absorbed update batches
          last_update    the newest `apply_updates` stats dict (also carries
                         per-pool `shipped` = engine.last_ship) or None
          shard_delta    graph.partition.SHARD_DELTA_STATS process counters
                         (full_reslice / short_circuit overlay re-slices)
          pools          per-algo (cohort groups aggregated: slots and
                         engine_queries summed, steps/tele from the leaves,
                         `cohorts` = leaf count): slots, engine_queries,
                         steps, queue depths/quotas/weights, placement kind,
                         and — when telemetry is on — `tele` (cumulative
                         named engine counters, see obs.TELE_FIELDS) +
                         `last_iter` (newest iteration-log sample) +
                         `imbalance` ({shard_edges: per-shard cumulative
                         scan plane, skew: max/mean}, DESIGN.md §14) +
                         `audit` (push/pull decision-audit summary: logged /
                         push / pull / mode_switches / compact_dense
                         counts, the controller thresholds, and the newest
                         record) + `shipped`; degraded shadow pools appear
                         as '<algo>@degraded' entries with a `degraded` flag
          slo            {"enabled": bool, deadline_missed/dropped/degraded/
                         preempted counts (obs.SLO_FIELDS, always live),
                         "policy": SLOPolicy.describe() or None,
                         "cohort_affinity": tenant -> pinned cohort list}
          health         HealthMonitor.snapshot() (DESIGN.md §14): P²
                         latency quantiles {p50/p95/p99_s, n} over the whole
                         stream + windowed {completions, deadline_missed,
                         miss_rate, burn_per_s, goodput, dropped} +
                         queue_depth {last, peak}; {"enabled": False} when
                         the monitor is off
          obs            Observability.snapshot(): metrics registry dump
                         (counters/gauges/histogram p50-p95-p99 summaries)
                         + span recorder totals + health snapshot + flight
                         ring occupancy; {"enabled": False} when off

        Reading it never issues a device transfer: telemetry values come
        from the host-side iteration log the pump already harvested."""
        from repro.graph.partition import SHARD_DELTA_STATS

        pools = {}
        for name, grp in self.pool_groups.items():
            p = grp[0]
            d = {
                "slots": sum(q.slots for q in grp),
                "cohorts": len(grp),
                "engine_queries": sum(q.engine_queries for q in grp),
                "steps": max(q.steps for q in grp),
                "queued": sum(len(q) for q in self.queues[name].values()),
                "queue_quota": self.queue_quota[name],
                "weight": self.weights[name],
                "placement": (
                    p.placement.kind if hasattr(p, "placement") else "single"
                ),
                "tenant_queued": {
                    t: len(q) for t, q in self.queues[name].items()
                },
                "tenant_quota": {
                    t: self.tenant_quota[(name, t)] for t in self.tenants
                },
            }
            if hasattr(p, "engine"):
                d["shipped"] = dict(p.engine.last_ship)
            if self.obs.enabled and any(q.iter_log for q in grp):
                # cumulative counters sum across cohort leaves; the sample
                # fields come from the most recently stepped leaf
                logged = [q for q in grp if q.iter_log]
                tele_sum = np.sum(
                    [np.asarray(q.iter_log[-1]["tele"]) for q in logged],
                    axis=0)
                last = max((q.iter_log[-1] for q in logged),
                           key=lambda e: e["step"])
                d["tele"] = tele_dict(tele_sum)
                d["last_iter"] = {
                    "step": last["step"], "gmode": last["gmode"],
                    "union_fe": last["union_fe"],
                    "overflow": last["overflow"], "live": last["live"],
                }
                plane = self._group_plane(grp)
                if plane.size:
                    d["imbalance"] = {
                        "shard_edges": [int(x) for x in plane],
                        "skew": skew_ratio(plane),
                    }
                audits = [a for q in logged for a in q.audit_log]
                if audits:
                    d["audit"] = {
                        "logged": len(audits),
                        "push": sum(a["mode"] == "push" for a in audits),
                        "pull": sum(a["mode"] == "pull" for a in audits),
                        "mode_switches": sum(a["switched"] for a in audits),
                        "compact_dense_fallbacks": sum(
                            a["compact_dense_d"] for a in audits),
                        "alpha_threshold": p._audit_alpha_edges,
                        "edge_cap": int(p.cfg.edge_cap),
                        "last": max(audits, key=lambda a: a["step"]),
                    }
            pools[name] = d
        for name, p in self.degraded_pools.items():
            d = {
                "slots": p.slots,
                "engine_queries": p.engine_queries,
                "steps": p.steps,
                "placement": "single",
                "degraded": True,
            }
            if self.obs.enabled and p.iter_log:
                last = p.iter_log[-1]
                d["tele"] = tele_dict(last["tele"])
            pools[p.name] = d
        return {
            "completed": len(self.completions),
            "queued": self._queued(),
            "rejected": self.rejected,
            "inflight": len(self._inflight_sources),
            "cache": self.cache.stats(),
            "graph_version": self.graph_version,
            "graph": {
                "n_nodes": self.g.n_nodes,
                "n_edges": self.g.n_edges,
                "streaming": self.sg.stats() if self.sg is not None else None,
            },
            "updates": len(self.update_log),
            "last_update": self.update_log[-1] if self.update_log else None,
            "shard_delta": dict(SHARD_DELTA_STATS),
            "pools": pools,
            "slo": {
                "enabled": self.slo is not None,
                **self.slo_counts,
                "policy": (self.slo.describe()
                           if self.slo is not None else None),
                "cohort_affinity": {
                    t: list(v) for t, v in self.cohort_affinity.items()},
            },
            "health": self.obs.health.snapshot(),
            "obs": self.obs.snapshot(),
        }
