"""Slot scheduler: continuous batching of graph point queries.

`launch/serve.py` demos slot-recycling admission for LM decode; this module
is that loop generalized into a reusable serving layer for ACC graph
queries. The analogy to SIMD-X JIT task management is direct: a bounded
static structure (S query lanes per algorithm, fixed shapes, one compiled
step) absorbs an irregular request stream (arrivals of arbitrary sources
and algorithms), with overflow handled by a bounded queue + backpressure
instead of device-side reallocation.

Pieces:

  * `AlgoPool` — S lanes of `batch_engine.BatchState` for ONE program.
    Admission writes a freshly initialized query into a done lane (a jitted
    column write); one `step()` advances every live lane one iteration;
    harvest extracts converged lanes and frees them. Lanes converge and are
    recycled MID-FLIGHT — queries never wait for the batch.
  * `GraphServer` — per-algorithm pools behind one bounded FIFO request
    queue (`submit` returns False when the queue is full — backpressure for
    the caller to retry/shed), fronted by the LRU `ResultCache`: a hit
    completes the request without touching a pool.

Exactness note: a lane admitted into a half-busy pool sees consensus
push/pull decisions influenced by its batch-mates, so its mode *sequence*
can differ from a solo run; results are still bit-identical for the
idempotent/min programs and pull-only programs served here (see
batch_engine's module docstring for the argument).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acc import ACCProgram
from repro.core.engine import EngineConfig
from repro.graph.csr import Graph
from repro.graph.packing import EllPack
from repro.serving import batch_engine as B
from repro.serving.cache import ResultCache, make_key


class QueueFull(Exception):
    """Raised by `submit(..., strict=True)` when the request queue is full."""


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    algo: str
    source: int


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    algo: str
    source: int
    result: np.ndarray          # (n,) primary metadata field
    iterations: int
    from_cache: bool


def default_config(g: Graph, max_iters: int = 4096) -> EngineConfig:
    """Serving-friendly engine config: full frontier cap (dense masks can't
    overflow), a modest push edge budget (the consensus controller pulls on
    heavy iterations anyway, so a lean push buffer keeps light iterations
    cheap)."""
    n, m = g.n_nodes, g.n_edges
    return EngineConfig(
        frontier_cap=n, edge_cap=max(1, min(m, 2 * n)), max_iters=max_iters
    )


class AlgoPool:
    """Fixed query slots for one ACC program over one graph."""

    def __init__(self, name: str, program: ACCProgram, g: Graph, pack: EllPack,
                 cfg: EngineConfig, slots: int, result_field: Optional[str] = None):
        assert slots >= 1
        self.name = name
        self.program = program
        self.result_field = result_field or program.primary
        self.g = g
        self.pack = pack
        self.cfg = cfg
        self.slots = slots
        self.lane_rid: List[Optional[int]] = [None] * slots
        # all lanes start inactive (done=True, empty frontiers)
        self.state = B.init_batch(
            program, g, cfg,
            jnp.zeros((slots,), jnp.int32),
            done=jnp.ones((slots,), bool),
        )
        # graph/pack are TRACED pytree args (not closure constants), so the
        # CSR/ELL arrays are not baked into each pool's executable — pools
        # over the same graph share the device buffers.
        self._step = jax.jit(
            lambda st, g_, pack_: B.make_batched_step(program, g_, pack_, cfg)(st)
        )
        self._admit = jax.jit(
            lambda st, source, lane, g_: _admit_lane(program, g_, cfg, st, source, lane)
        )
        self.engine_queries = 0
        self.steps = 0

    # -- scheduling interface ------------------------------------------------

    def free_lanes(self) -> List[int]:
        done = np.asarray(self.state.done)
        return [i for i in range(self.slots) if self.lane_rid[i] is None and done[i]]

    def live(self) -> bool:
        return any(r is not None for r in self.lane_rid)

    def admit(self, lane: int, rid: int, source: int) -> None:
        assert self.lane_rid[lane] is None
        self.state = self._admit(
            self.state, jnp.int32(source), jnp.int32(lane), self.g
        )
        self.lane_rid[lane] = rid
        self.engine_queries += 1

    def step(self) -> None:
        if self.live():
            self.state = self._step(self.state, self.g, self.pack)
            self.steps += 1

    def harvest(self) -> List[tuple]:
        """(lane, rid, result, iterations) for every lane that converged."""
        if not self.live():
            return []
        done = np.asarray(self.state.done)
        out = []
        for lane, rid in enumerate(self.lane_rid):
            if rid is None or not done[lane]:
                continue
            res = np.asarray(self.state.m[self.result_field][:-1, lane])
            out.append((lane, rid, res, int(self.state.it[lane])))
            self.lane_rid[lane] = None
        return out


def _admit_lane(program, g, cfg, st: B.BatchState, source, lane) -> B.BatchState:
    """Write one freshly initialized query into lane `lane` (jitted)."""
    one = B.init_batch(program, g, cfg, source[None])
    m = {k: st.m[k].at[:, lane].set(one.m[k][:, 0]) for k in st.m}
    active = st.active.at[:, lane].set(one.active[:, 0])
    st = st._replace(
        m=m,
        active=active,
        count=st.count.at[lane].set(one.count[0]),
        mode=st.mode.at[lane].set(one.mode[0]),
        it=st.it.at[lane].set(0),
        done=st.done.at[lane].set(one.done[0]),
        push_iters=st.push_iters.at[lane].set(0),
        pull_iters=st.pull_iters.at[lane].set(0),
        switches=st.switches.at[lane].set(0),
        mode_trace=st.mode_trace.at[lane].set(one.mode_trace[0]),
    )
    union_fe, overflow = B._union_volume(g.out, cfg, active)
    st = st._replace(union_fe=union_fe, overflow=overflow)
    return st._replace(gmode=B._consensus_mode(program, cfg, g.n_edges, st))


class GraphServer:
    """Batched multi-query graph serving: cache -> queue -> slot pools."""

    def __init__(
        self,
        g: Graph,
        pack: EllPack,
        programs: Dict[str, ACCProgram],
        slots: "int | Dict[str, int]" = 8,
        cfg: Optional[EngineConfig] = None,
        queue_cap: int = 256,
        cache_capacity: int = 1024,
        graph_version: int = 0,
        result_fields: Optional[Dict[str, str]] = None,
    ):
        cfg = cfg or default_config(g)
        self.g = g
        self.graph_version = graph_version
        self.queue: deque = deque()
        self.queue_cap = queue_cap
        self.cache = ResultCache(cache_capacity)
        self.pools: Dict[str, AlgoPool] = {}
        result_fields = result_fields or {}
        for name, prog in programs.items():
            s = slots[name] if isinstance(slots, dict) else slots
            self.pools[name] = AlgoPool(
                name, prog, g, pack, cfg, s,
                result_field=result_fields.get(name),
            )
        self._next_rid = 0
        self._inflight_sources: Dict[int, int] = {}
        self.completions: List[Completion] = []
        self.rejected = 0

    # -- request side --------------------------------------------------------

    def submit(self, algo: str, source: int, strict: bool = False) -> Optional[int]:
        """Enqueue a query; returns its rid, or None when the queue is full
        (backpressure — caller sheds or retries; `strict=True` raises)."""
        if algo not in self.pools:
            raise KeyError(f"no pool for algorithm {algo!r}")
        rid = self._next_rid
        key = make_key(self.graph_version, algo, source)
        hit = self.cache.get(key)
        if hit is not None:
            self._next_rid += 1
            self.completions.append(Completion(
                rid=rid, algo=algo, source=int(source), result=hit,
                iterations=0, from_cache=True,
            ))
            return rid
        if len(self.queue) >= self.queue_cap:
            self.rejected += 1
            if strict:
                raise QueueFull(f"queue at capacity {self.queue_cap}")
            return None
        self._next_rid += 1
        self.queue.append(Request(rid=rid, algo=algo, source=int(source)))
        return rid

    # -- serving loop --------------------------------------------------------

    def pump(self) -> List[Completion]:
        """One scheduling round: admit from the queue into free lanes, one
        batched step per live pool, harvest converged lanes. Returns the
        completions produced this round."""
        # admission (FIFO per algorithm; requests for saturated pools wait)
        free = {name: deque(pool.free_lanes()) for name, pool in self.pools.items()}
        still_waiting: deque = deque()
        while self.queue:
            req = self.queue.popleft()
            lanes = free[req.algo]
            if lanes:
                self.pools[req.algo].admit(lanes.popleft(), req.rid, req.source)
                self._inflight_sources[req.rid] = req.source
            else:
                still_waiting.append(req)
        self.queue = still_waiting

        new: List[Completion] = []
        for name, pool in self.pools.items():
            pool.step()
            for _lane, rid, result, iters in pool.harvest():
                # rid -> source lookup: completions carry it forward
                comp = Completion(
                    rid=rid, algo=name, source=self._source_of(rid, name, result),
                    result=result, iterations=iters, from_cache=False,
                )
                new.append(comp)
        # cache fill
        for comp in new:
            self.cache.put(
                make_key(self.graph_version, comp.algo, comp.source), comp.result
            )
        self.completions.extend(new)
        return new

    def _source_of(self, rid: int, algo: str, result) -> int:
        return self._inflight_sources.pop(rid)

    def drain(self, max_rounds: int = 100000) -> List[Completion]:
        """Pump until the queue and every pool are empty; returns ALL
        completions accumulated so far (cache hits included)."""
        rounds = 0
        while self.queue or any(p.live() for p in self.pools.values()):
            self.pump()
            rounds += 1
            if rounds >= max_rounds:
                raise RuntimeError("drain did not converge")
        return self.completions

    def stats(self) -> dict:
        return {
            "completed": len(self.completions),
            "queued": len(self.queue),
            "rejected": self.rejected,
            "cache": self.cache.stats(),
            "pools": {
                name: {
                    "slots": p.slots,
                    "engine_queries": p.engine_queries,
                    "steps": p.steps,
                }
                for name, p in self.pools.items()
            },
        }
