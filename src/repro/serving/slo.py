"""Deadline-aware serving policy: admission drop, degradation, preemption.

The SLO subsystem's policy half (DESIGN.md §13). `GraphServer(slo=...)`
threads an :class:`SLOPolicy` through the scheduling loop; the load half
(open-loop workload generation + replay harness) lives in `repro.slo`,
which re-exports this module so callers import one package.

SIMD-X's just-in-time task management spends GPU cycles only on work that
still matters; at the serving layer the analogous discipline is spending
LANE time only on queries that can still meet their deadline:

  * **admission-time drop** — a queued query whose deadline has already
    passed (or provably cannot be met: `now + hopeless_margin x
    EWMA(resident)` past the deadline) is completed as `dropped` instead
    of occupying a lane it cannot use;
  * **pressure-triggered degradation** — under queue pressure, residual
    push programs (`ppr_delta`) admit into a shadow pool running a
    LOOSENED tolerance (`tol x degrade_factor`): the query finishes in
    fewer push iterations at documented accuracy loss, flagged
    `degraded` and never cached under the bit-exact key;
  * **preemption** — a long-resident lane blocking a pool whose queue
    holds deadline-critical work is evicted mid-run; for residual-push
    programs the FULL metadata columns (rank, resid, send, deg) are
    harvested into the result cache and the query is re-queued at the
    front — on re-admission it resumes the fixpoint from the saved
    residuals via the shared `reseed_from_residuals` path, so preempted
    work is resumable, not wasted.

Every decision is host-side and O(queue length); the policy never touches
the device beyond the rare preempt/resume column reads.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.acc import ACCProgram


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Knobs for deadline-aware scheduling (all trip points documented in
    DESIGN.md §13's policy table). Deadlines themselves arrive per query
    via `GraphServer.submit(deadline_ms=...)`; without a policy the server
    still *accounts* misses — the policy adds drop/degrade/preempt
    *actions*."""

    #: drop queued queries whose deadline has already passed (checked at
    #: submit and at the head of every pump's admission phase)
    drop_expired: bool = True
    #: also drop when `now + hopeless_margin * EWMA(pool resident)` is past
    #: the deadline — the query cannot finish even if admitted right now.
    #: 0 disables the estimate (only already-expired queries drop).
    hopeless_margin: float = 0.0

    #: algorithms (residual-push programs) that get a degraded shadow pool
    degrade_algos: Tuple[str, ...] = ()
    #: tolerance multiplier for the degraded variant (`tol x factor`)
    degrade_factor: float = 8.0
    #: lanes in each degraded shadow pool
    degrade_slots: int = 4
    #: pressure trigger: an algorithm's total queued count at/above this
    #: routes overflow admissions to the degraded pool
    degrade_queue_depth: int = 4
    #: pressure trigger (alternative): any queued query's deadline slack
    #: below this many seconds counts as pressure
    degrade_slack_s: float = 0.0

    #: enable preemption of long-resident lanes (residual-push pools only —
    #: their partial state is resumable; evicting a min-program lane would
    #: discard work)
    preempt: bool = False
    #: trigger: preempt when the smallest queued deadline slack is below
    #: max(preempt_slack_s, preempt_slack_factor * EWMA(pool resident))
    preempt_slack_s: float = 0.0
    preempt_slack_factor: float = 1.0
    #: a victim lane must have been resident at least this long
    preempt_min_resident_s: float = 0.0
    #: per-query preemption budget — caps requeue churn
    max_preempts: int = 1

    #: consensus-cohort step cadence (single-device cohort groups only).
    #: On a synchronous host backend a batched step costs the same whether
    #: one lane or all Q are live, so the isolation lever is WHICH leaves
    #: step each pump round: a cohort leaf holding any deadline-bearing
    #: resident query may burst up to `cohort_burst` steps per round...
    cohort_burst: int = 1
    #: ...while a best-effort-only leaf steps every `best_effort_stride`-th
    #: round (1 = every round, i.e. no cadence shaping — the default keeps
    #: cohort scheduling bit-identical to pre-policy serving)
    best_effort_stride: int = 1

    def describe(self) -> dict:
        """JSON-able summary for `GraphServer.stats()['slo']['policy']`."""
        return {
            "drop_expired": self.drop_expired,
            "hopeless_margin": self.hopeless_margin,
            "degrade_algos": list(self.degrade_algos),
            "degrade_factor": self.degrade_factor,
            "degrade_slots": self.degrade_slots,
            "degrade_queue_depth": self.degrade_queue_depth,
            "degrade_slack_s": self.degrade_slack_s,
            "preempt": self.preempt,
            "preempt_slack_s": self.preempt_slack_s,
            "preempt_slack_factor": self.preempt_slack_factor,
            "preempt_min_resident_s": self.preempt_min_resident_s,
            "max_preempts": self.max_preempts,
            "cohort_burst": self.cohort_burst,
            "best_effort_stride": self.best_effort_stride,
        }


def degraded_variant(program: ACCProgram, factor: float) -> ACCProgram:
    """Loosened-tolerance variant of a residual-push program.

    The degraded pool's program converges when the residual clears
    `factor*tol` times its declared threshold rule instead of `tol` — by the
    residual invariant the served estimate is within `factor*tol` per unit
    of threshold-weighted residual mass of the exact answer, reached in
    strictly fewer push iterations. Only residual programs degrade this way
    (min/max programs have nothing to loosen), and the rebuild goes through
    the program's OWN declared `with_tol` contract — metadata dispatch, no
    name matching, so any residual-form program in the catalog degrades."""
    assert factor > 1.0, factor
    assert program.param("kind") == "residual", (
        f"{program.name} is not a residual-push program — nothing to loosen")
    if program.with_tol is None:
        raise ValueError(
            f"{program.name!r} declares no tolerance-rebuild contract "
            "(ACCProgram.with_tol) — cannot build a degraded variant")
    return program.with_tol(float(program.param("tol")) * float(factor))
