"""Batched multi-query graph serving (DESIGN.md §7).

The layer between the single-query ACC engine and serving traffic:

  batch_engine.py -- Q stacked point queries, one fused push-pull loop
                     (vertex-major layout, union-frontier push, consensus
                     JIT controller, per-query done-masking)
  scheduler.py    -- slot pools + bounded request queue with backpressure;
                     continuous batching with mid-flight lane recycling
  cache.py        -- graph-version-keyed LRU so hot queries short-circuit
  sharded.py      -- the batched loop under shard_map on a ('data','model')
                     mesh: query-sharded replicas or 1-D edge partitions,
                     with a psum'd global consensus controller (DESIGN.md §9)
  placement.py    -- pool placement layer: sharded pools behind GraphServer
  slo.py          -- deadline-aware policy: admission drop, degraded shadow
                     pools, lane preemption/resume (DESIGN.md §13; the load
                     harness lives in `repro.slo`)

Entry points: `GraphServer` for request streams (pass `mesh`/`placements`
for sharded pools), `run_batch` / `run_sharded` for one fixed batch,
`launch/serve_graph.py --mesh DxS` for the CLI driver.
"""

from repro.serving.batch_engine import (  # noqa: F401
    BatchState,
    init_batch,
    make_batched_step,
    query_result,
    run_batch,
    run_sequential,
    run_state,
)
from repro.serving.cache import ResultCache, make_key  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    AlgoPool,
    Completion,
    GraphServer,
    QueueFull,
    Request,
    default_config,
)
from repro.serving.slo import SLOPolicy, degraded_variant  # noqa: F401
from repro.serving.placement import (  # noqa: F401
    Placement,
    ShardedAlgoPool,
    make_serving_mesh,
)
from repro.serving.sharded import (  # noqa: F401
    ShardedBatchEngine,
    run_sharded,
    shard_sources,
)

__all__ = [
    "Placement",
    "ShardedAlgoPool",
    "ShardedBatchEngine",
    "make_serving_mesh",
    "run_sharded",
    "shard_sources",
    "BatchState",
    "init_batch",
    "make_batched_step",
    "query_result",
    "run_batch",
    "run_sequential",
    "run_state",
    "ResultCache",
    "make_key",
    "AlgoPool",
    "Completion",
    "GraphServer",
    "QueueFull",
    "Request",
    "default_config",
    "SLOPolicy",
    "degraded_variant",
]
