"""Pool placement: sharded serving pools behind the GraphServer (DESIGN.md §9).

`GraphServer` pools declare WHERE they run: unplaced pools stay the
single-device `AlgoPool`; placed pools wrap a
:class:`~repro.serving.sharded.ShardedBatchEngine` on the server's
('data', 'model') mesh:

    Placement('replicated', 8)    # query-sharded: Q over 8 'data' shards,
                                  # graph/pack/delta broadcast to replicas
    Placement('edge_sharded', 4)  # 1-D edge partition over 4 'model' shards

The scheduler's contract is unchanged — free_lanes / admit / step / harvest
/ set_graph / readmit — so admission, continuous batching, backpressure and
`apply_updates` (overlay swap + selective LRU invalidation) run through
sharded pools untouched. Two placement-specific behaviors:

  * **shard-local lane routing**: lane l of a Q-lane pool lives on 'data'
    shard l // (Q/D) (jax shards the trailing axis in contiguous blocks), so
    `free_lanes` orders free lanes round-robin ACROSS shards — admissions
    spread over the mesh instead of piling onto shard 0.
  * **cache keys**: edge-sharded pools of sum-combiner programs produce
    results that differ from the replicated/single-device bit pattern by one
    cross-shard reassociation, so their cache entries carry a
    ('placement', 'edge_sharded') param — a placement change can never serve
    a bitwise-foreign cached result.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acc import ACCProgram
from repro.core.engine import EngineConfig
from repro.graph.csr import EdgeDelta, Graph
from repro.graph.packing import EllPack
from repro.serving.scheduler import _admit_lane, _LanePool
from repro.serving.sharded import (
    DATA_AXIS,
    MODEL_AXIS,
    ShardedBatchEngine,
    make_serving_mesh,
)


@dataclasses.dataclass(frozen=True)
class Placement:
    """Where one pool's lanes and edges live on the serving mesh."""

    kind: str                     # 'replicated' | 'edge_sharded'
    n_shards: int = 1
    consensus: str = "global"     # pools step collectively -> global only

    def __post_init__(self):
        assert self.kind in ("replicated", "edge_sharded"), self.kind
        assert self.n_shards >= 1

    @classmethod
    def of(cls, spec) -> "Placement":
        """Coerce ('replicated'|'edge_sharded', n) tuples / bare kind strings
        (n_shards=1) / Placement instances."""
        if isinstance(spec, Placement):
            return spec
        if isinstance(spec, str):
            return cls(spec)
        kind, n_shards = spec
        return cls(kind, int(n_shards))

    def check_mesh(self, mesh) -> None:
        d = int(mesh.shape[DATA_AXIS])
        s = int(mesh.shape[MODEL_AXIS])
        if self.kind == "replicated":
            assert self.n_shards == d, (
                f"replicated placement wants {self.n_shards} query shards, "
                f"mesh 'data' axis has {d}")
        else:
            assert self.n_shards == s, (
                f"edge_sharded placement wants {self.n_shards} edge shards, "
                f"mesh 'model' axis has {s}")


class ShardedAlgoPool(_LanePool):
    """Fixed query slots for one ACC program, sharded across a mesh.

    Shares `scheduler._LanePool`'s lane bookkeeping with the single-device
    `AlgoPool`, so the GraphServer drives both kinds through one loop.
    `slots` is the TOTAL lane count across query shards (must divide by the
    mesh 'data' axis)."""

    def __init__(self, name: str, program: ACCProgram, g: Graph,
                 pack: EllPack, cfg: EngineConfig, slots: int, mesh,
                 placement, result_field: Optional[str] = None,
                 delta: Optional[EdgeDelta] = None, telemetry: bool = False):
        self.placement = Placement.of(placement)
        self.placement.check_mesh(mesh)
        self.name = name
        self.program = program
        # served field defaults to the program's declared 'result' param
        # (see scheduler.AlgoPool)
        self.result_field = result_field or program.param(
            "result", program.primary)
        self.cfg = cfg
        self.slots = slots
        self.n_query_shards = int(mesh.shape[DATA_AXIS])
        assert slots % self.n_query_shards == 0, (
            f"{slots} lanes do not divide over {self.n_query_shards} "
            "query shards")
        self.engine = ShardedBatchEngine(
            program, g, pack, cfg, mesh, placement=self.placement.kind,
            consensus=self.placement.consensus, delta=delta,
            telemetry=telemetry)
        self.g, self.pack, self.delta = (
            self.engine.g, self.engine.pack, self.engine.delta)
        self.lane_rid: List[Optional[int]] = [None] * slots
        self.state = self.engine.init(
            jnp.zeros((slots,), jnp.int32),
            done=jnp.ones((slots,), bool))
        self._make_admit()
        self._refresh_live_deg()
        #: extra cache-key params (see module docstring)
        self.cache_params = (
            (("placement", "edge_sharded"),)
            if (self.placement.kind == "edge_sharded"
                and program.combiner.name == "sum")
            else ())
        # pools with a declared streaming-resume contract cache its
        # `resume_fields` beyond the result plane (see scheduler.AlgoPool)
        from repro.streaming.incremental import resume_fields

        self.cache_extra_fields = tuple(
            f for f in resume_fields(program) if f != self.result_field)
        self.engine_queries = 0
        self.steps = 0
        self._init_obs(telemetry)

    # -- scheduling interface: live/admit/harvest/readmit from _LanePool ----

    def free_lanes(self) -> List[int]:
        """Free lanes ordered round-robin across query shards, so successive
        admissions land on different shards (shard-local slot routing)."""
        per = self.slots // self.n_query_shards
        return sorted(super().free_lanes(),
                      key=lambda lane: (lane % per, lane // per))

    def _make_admit(self) -> None:
        """(Re)build the jitted admission closure. Admission reuses the
        single-device lane write under plain jit: GSPMD partitions the
        column update over the sharded state, and the out_shardings pin
        keeps the state's layout stable across admits. Edge-sharded
        admission is CSR-FREE (DESIGN.md §11): the jitted write consumes
        only the static graph dims + the pool's cached (n,) live-degree
        vector — the O(m) adjacency never enters the call (and the
        edge-sharded scan never truncates, so the push-only capacity check
        is skipped too). The dims are baked into the closure, so
        `set_graph` re-makes it when a rebuild changes the edge count."""
        program, cfg = self.program, self.cfg
        if self.placement.kind == "edge_sharded":
            from repro.serving.batch_engine import GraphDims

            dims = GraphDims(self.engine.n, self.engine.n_edges)
            self._admit_dims = dims
            self._admit = jax.jit(
                lambda st, source, lane, g_, d_, deg_: _admit_lane(
                    program, dims, cfg, st, source, lane, check_caps=False,
                    deg=deg_),
                out_shardings=self.engine.state_shardings,
            )
        else:
            self._admit_dims = None
            self._admit = jax.jit(
                lambda st, source, lane, g_, d_, deg_: _admit_lane(
                    program, g_, cfg, st, source, lane, delta=d_, deg=deg_),
                out_shardings=self.engine.state_shardings,
            )

    def _admit_graph(self):
        # CSR-free: no graph view enters the jitted edge-sharded admission
        return None if self.placement.kind == "edge_sharded" else self.g

    def _admit_delta(self):
        return None if self.placement.kind == "edge_sharded" else self.delta

    def _refresh_live_deg(self) -> None:
        # the engine already counted + mesh-placed the live-degree vector
        # for this graph version — admission reuses it instead of recounting
        if self.placement.kind == "edge_sharded":
            self.live_deg = self.engine.deg
        else:
            super()._refresh_live_deg()

    def step(self) -> None:
        if self.live():
            self.state = self.engine.step(self.state)
            self.steps += 1

    # -- streaming support ---------------------------------------------------

    def set_graph(self, g: Graph, pack: EllPack,
                  delta: Optional[EdgeDelta]) -> None:
        """Swap updated overlay views into every shard: replicated pools
        broadcast the new views to the replicas, edge-sharded pools re-slice
        the edge partition and the per-shard delta (same shapes — no
        recompile). Masked-pull partial caches rebuild at identity exactly
        like the single-device pool, placed on the mesh."""
        self.engine.set_graph(g, pack, delta)
        self.g, self.pack, self.delta = (
            self.engine.g, self.engine.pack, self.engine.delta)
        if (self._admit_dims is not None
                and self._admit_dims.n_edges != self.engine.n_edges):
            # an overflow rebuild changed m: re-bake the CSR-free admit
            # closure's static dims so post-rebuild consensus decisions see
            # the current edge count
            self._make_admit()
        self._refresh_live_deg()
        self._reset_masked_pull_cache()

    def _place_pseg(self, pseg: tuple) -> tuple:
        return tuple(
            jax.device_put(p, sh)
            for p, sh in zip(pseg, self.engine.state_shardings.pseg))

    def _place_state(self, st):
        """Re-place a host-rebuilt state (residual resume) on the mesh."""
        return jax.device_put(st, self.engine.state_shardings)


__all__ = [
    "Placement",
    "ShardedAlgoPool",
    "make_serving_mesh",
    "DATA_AXIS",
    "MODEL_AXIS",
]
