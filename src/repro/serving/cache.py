"""LRU result cache for the graph serving layer.

Point queries are heavily skewed in serving traffic (hot sources, repeated
per-user PPR) — a small LRU in front of the batched engine short-circuits
repeats without touching a slot. Keys bind the GRAPH VERSION so a graph swap
(rebuild, streaming update) invalidates every cached result implicitly:
bump `GraphServer.graph_version` and old keys simply never match again.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable, NamedTuple, Optional, Tuple


class CachedEntry(NamedTuple):
    """A cache value carrying resumable state beyond the served result.

    Residual-push pools (`ppr_delta`) store `(rank, {resid: ...})` so a
    DIRTY cached entry can refresh incrementally across a streaming update
    (Maiter-correct the residuals, resume the fixpoint) instead of dropping
    — a bare (n,) rank is not resumable (ROADMAP streaming 3(e), DESIGN.md
    §11). `result` is what a cache hit serves; `extras` maps extra metadata
    field names to their (n,) planes."""

    result: Any
    extras: dict


def served_result(value):
    """The (n,) result a cache hit serves, whatever the stored shape."""
    return value.result if isinstance(value, CachedEntry) else value


def make_key(graph_version: int, algo: str, source: int,
             params: Tuple = ()) -> Tuple:
    """Canonical cache key: (graph version, algorithm, source, extra params).

    `params` must be hashable; `GraphServer` passes each pool's
    `cache_params` — () for single-device and replicated pools (their
    results are the bitwise reference), and (('placement', 'edge_sharded'),)
    for edge-partitioned pools of sum-combiner programs, whose results
    differ from the reference by one cross-shard reassociation (DESIGN.md
    §9) and must never be served under the bit-exact key. Callers serving
    several parameterizations of one algorithm (e.g. two PPR dampings as
    separate pools) put the distinguishing (name, value) pairs here too.
    """
    return (int(graph_version), str(algo), int(source), tuple(params))


class ResultCache:
    """Bounded LRU: `get` refreshes recency, `put` evicts the stalest entry.

    Values are whatever the caller stores (host numpy result arrays here —
    keeping cached results off-device frees HBM for in-flight queries).
    """

    def __init__(self, capacity: int = 1024):
        assert capacity >= 0
        self.capacity = capacity
        self._entries: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        #: entries lost to STALENESS rather than capacity: explicit
        #: `invalidate` hits, plus the take_version entries a streaming
        #: update could not retain/refresh (the caller reports those via
        #: `note_invalidated` — the cache cannot see which taken entries
        #: come back). The unified stats surface reads this (DESIGN.md §12).
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def get(self, key: Hashable) -> Optional[Any]:
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any) -> None:
        if self.capacity == 0:
            return
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = value
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def pop(self, key: Hashable) -> Optional[Any]:
        """Remove and return an entry WITHOUT touching hit/miss/invalidation
        accounting. This is for internal scheduler bookkeeping traffic —
        e.g. reclaiming a preempted query's parked partial state at
        re-admission (DESIGN.md §13) — which is not request-serving activity
        and must not skew the cache's observable hit rate."""
        return self._entries.pop(key, None)

    def invalidate(self, key: Hashable) -> bool:
        hit = self._entries.pop(key, None) is not None
        if hit:
            self.invalidations += 1
        return hit

    def note_invalidated(self, n: int) -> None:
        """Record `n` entries dropped by a streaming update's selective
        invalidation pass (`take_version` entries never re-`put`)."""
        self.invalidations += int(n)

    def take_version(self, graph_version: int) -> list:
        """Remove and return every entry keyed to `graph_version`, in recency
        order (stalest first), as (key, value) pairs.

        This is the mechanism under SELECTIVE invalidation on a streaming
        graph update (DESIGN.md §8): the caller re-`put`s the entries whose
        source survives the affected-region test under the new version
        (preserving relative recency), refreshes or drops the rest — instead
        of the wholesale version-bump invalidation."""
        keys = [k for k in self._entries
                if isinstance(k, tuple) and k and k[0] == graph_version]
        return [(k, self._entries.pop(k)) for k in keys]

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "size": len(self._entries),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "hit_rate": self.hits / total if total else 0.0,
        }
