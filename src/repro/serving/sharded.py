"""Sharded multi-device batched serving engine (DESIGN.md §9).

The batched vertex-major engine (`serving/batch_engine.py`) runs Q point
queries in one fused loop on ONE device. This module lifts that loop onto a
('data', 'model') device mesh with `shard_map`, along the two scaling axes
the repo already has layouts for (Gunrock's multi-GPU split, GraphBLAST's
SpMM view):

  * **query-sharded** (`placement='replicated'`): queries are embarrassingly
    parallel, so the Q axis splits over the 'data' mesh axis and the
    graph/pack/delta views replicate. Each shard runs the unmodified batched
    push/pull iteration on its Q/D lanes; the only cross-shard state is the
    JIT controller's input: per-shard union masks are `psum`-reduced over
    'data' into the exact global union, so the one scalar push/pull decision
    per iteration is a pure function of the same volumes the single-device
    consensus controller sees — the global mode sequence (and hence the mode
    trace) is identical to the single-device batched engine's.

  * **edge-partitioned** (`placement='edge_sharded'`): for graphs whose edge
    set outgrows one device, `graph/partition.py`'s 1-D edge shards split
    over the 'model' axis while metadata replicates within each mesh row.
    Each shard scans ITS edge partition per iteration (frontier-masked for
    push-semantics programs, unmasked for pull-only programs — the SpMM
    formulation), segment-combines locally into an (n+1, Q) partial, and the
    partials merge across shards with the combine monoid's all-reduce
    (`psum` for sum — implementable as psum_scatter+all_gather — and
    pmin/pmax for the idempotent monoids). Per-iteration device state
    touches only the shard's E/S edge triples + O(n·Q) metadata. Round 2
    (DESIGN.md §11): LIGHT iterations frontier-compact the shard scan
    (`cfg.shard_compact` — gather only union-frontier slots into a bounded
    buffer, switched by the consensus controller, dense fallback on
    overflow, bit-identical either way); admission and init are CSR-FREE
    (only the cached (n,) live-degree vector, never the O(m) adjacency);
    streaming updates ship only the CHANGED per-shard slices / replicated
    leaves (`set_graph` diffing, `last_ship`).

Exactness (§7 argument, unchanged): per-query metadata is a pure function
of per-query frontier trajectories; batch-mates and shard layout influence
only the mode sequence, and for idempotent min/max programs a push and a
pull iteration produce bit-identical metadata. Query-sharded results are
therefore bit-identical to the single-device batched engine for the whole
served suite (pull-only sum programs trivially so: identical iteration
structure, pinned reduction trees). Edge-partitioned results are bit-exact
for min/max programs (min/max are reassociation-free across the shard
merge); sum programs see one extra reassociation (the cross-shard psum) and
match to FP tolerance.

Consensus flavors:

  * `consensus='global'` (default): the psum'd controller above. Shards run
    in lockstep (the fused loop carries the psum'd live count so every shard
    exits the `while_loop` on the same trip); the mode trace equals the
    single-device trace (tests/test_sharded.py pins this on RMAT-12).
  * `consensus='local'`: each shard decides modes from its own union — NO
    collectives at all in replicated placement, so shards converge fully
    independently (results still bit-identical by idempotence; mode traces
    may diverge per shard — the regression test demonstrates the divergence
    the psum reduction exists to prevent). Fused runs only.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import frontier as F
from repro.core.acc import ACCProgram, Combiner
from repro.core.engine import PULL, PUSH, EngineConfig
from repro.graph import partition
from repro.graph.csr import EdgeDelta, Graph, live_degrees
from repro.graph.packing import EllPack
from repro.obs import (
    TELE_COMPACT_DENSE,
    TELE_COMPACT_HITS,
    TELE_LEN,
    TELE_PULL_EDGES,
    TELE_PUSH_EDGES,
)
from repro.serving import batch_engine as B

DATA_AXIS = "data"     # query shards
MODEL_AXIS = "model"   # edge shards

_SPEC_LEAF = lambda x: isinstance(x, P) or x is None  # noqa: E731


def make_serving_mesh(n_query_shards: int = 1, n_edge_shards: int = 1):
    """('data', 'model') mesh for sharded pools. Needs
    `n_query_shards * n_edge_shards` jax devices (force host devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=N for CPU meshes)."""
    devs = jax.devices()
    need = n_query_shards * n_edge_shards
    if len(devs) < need:
        raise RuntimeError(
            f"mesh ({n_query_shards}, {n_edge_shards}) needs {need} devices, "
            f"have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return compat.make_mesh(
        (n_query_shards, n_edge_shards), (DATA_AXIS, MODEL_AXIS),
        devices=devs[:need],
        axis_types=(compat.AxisType.Auto, compat.AxisType.Auto),
    )


def state_specs(st: B.BatchState, mesh=None) -> B.BatchState:
    """PartitionSpec tree for a BatchState: Q axis over 'data', vertex axis
    and consensus scalars replicated (the global controller keeps the
    scalars bitwise-equal across shards). With a mesh, the specs come from
    the logical-axis layer (`distributed/sharding.py`'s 'queries' rule), so
    the state layout collapses gracefully on meshes without a 'data' axis."""
    if mesh is not None:
        from repro.distributed import sharding as SH

        with SH.activate(mesh):
            qv = SH.spec(None, "queries")   # (n+1, Q) vertex-major
            ql = SH.spec("queries")         # (Q,) per-lane
            tr = SH.spec("queries", None)   # (Q, trace_len)
    else:
        qv, ql, tr = P(None, DATA_AXIS), P(DATA_AXIS), P(DATA_AXIS, None)
    return B.BatchState(
        m={k: qv for k in st.m},
        active=qv, count=ql, union_fe=P(), overflow=P(),
        mode=ql, it=ql, done=ql,
        push_iters=ql, pull_iters=ql, switches=ql,
        mode_trace=tr, gmode=P(),
        pseg=tuple(qv for _ in st.pseg),
        pull_dense=None if st.pull_dense is None else P(),
        hot=None if st.hot is None else qv,
        # cumulative telemetry counters are mesh-global (increments are
        # psum'd across shards inside the steps), hence replicated
        tele=None if st.tele is None else P(),
    )


def _replicated_specs(tree):
    return jax.tree.map(lambda _: P(), tree)


def _monoid_all_reduce(comb: Combiner, x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """All-reduce `x` across `axis` in the combine monoid. The idempotent
    monoids use pmin/pmax (reassociation-free -> bit-exact merge); sum uses
    psum (the psum_scatter + all_gather decomposition when XLA tiles it)."""
    if comb.name == "sum":
        return jax.lax.psum(x, axis)
    if comb.name == "min":
        return jax.lax.pmin(x, axis)
    if comb.name == "max":
        return jax.lax.pmax(x, axis)
    raise ValueError(comb.name)


def _global_union_volume(deg, cfg, mask, axis):
    """The single-device controller's (union_fe, overflow) reconstructed
    exactly across query shards: psum the per-shard union masks (union of
    unions, NOT a sum of volumes — overlapping frontiers must not double
    count), then measure the global union's out-edge volume."""
    local = jnp.any(mask, axis=-1).astype(jnp.int32)      # (n+1,)
    union = jax.lax.psum(local, axis) > 0
    fe = jnp.sum(jnp.where(union[:-1], deg, 0)).astype(jnp.int32)
    ucount = jnp.sum(union[:-1]).astype(jnp.int32)
    return fe, ucount > cfg.frontier_cap


def _live_count(st, axes) -> jnp.ndarray:
    live = jnp.sum(~st.done).astype(jnp.int32)
    for ax in axes:
        live = jax.lax.psum(live, ax)
    return live


def _normalize_scalars(st, comb_gmode_axes):
    """Deterministic consensus scalars at loop exit for flavors whose shards
    carry shard-local values (local consensus / per-row edge shards):
    aggregate volume, any-overflow, max mode — replicated by construction so
    the P() out_specs hold."""
    fe = jax.lax.psum(st.union_fe, comb_gmode_axes)
    ovf = jax.lax.psum(st.overflow.astype(jnp.int32), comb_gmode_axes) > 0
    gmode = jax.lax.pmax(st.gmode, comb_gmode_axes)
    st = st._replace(union_fe=fe, overflow=ovf, gmode=gmode)
    if st.tele is not None:
        # fused edge-sharded bodies keep tele 'data'-local (a psum over
        # 'data' inside the while_loop would deadlock: rows exit at
        # independent trip counts); globalize once here at loop exit.
        # Within a 'model' group the body already summed, so 'data' only.
        st = st._replace(tele=jax.lax.psum(st.tele, DATA_AXIS))
    return st


# ---------------------------------------------------------------------------
# per-shard step bodies
# ---------------------------------------------------------------------------


def _make_replicated_step(program: ACCProgram, cfg: EngineConfig,
                          n_edges: int, consensus: str):
    """One query-shard iteration: the unmodified single-device batched step
    on the shard's lanes, with the controller inputs globalized by psum when
    `consensus='global'`."""

    def step(st: B.BatchState, g: Graph, pack: EllPack,
             delta: Optional[EdgeDelta]) -> B.BatchState:
        if program.modes == "push":
            new = B._push_step(program, g.out, cfg, st, delta)
        elif program.modes == "pull":
            new = B._pull_step(program, pack, cfg, st, g.out)
        else:
            new = jax.lax.cond(
                st.gmode == PULL,
                lambda s: B._pull_step(program, pack, cfg, s, g.out),
                lambda s: B._push_step(program, g.out, cfg, s, delta),
                st,
            )
        if consensus == "global":
            # the psum sits OUTSIDE the push/pull cond: every shard executes
            # it unconditionally, so the collective schedule is uniform
            deg = g.out.row_ptr[1:] - g.out.row_ptr[:-1]
            fe, ovf = _global_union_volume(deg, cfg, new.active, DATA_AXIS)
            new = new._replace(union_fe=fe, overflow=ovf)
            if st.tele is not None:
                # the inner step added this shard's lanes' increments; the
                # carried accumulator is mesh-global (replicated spec), so
                # globalize the increment the same way as the controller
                # inputs — unconditional psum, uniform collective schedule
                inc = new.tele - st.tele
                if inc.shape[0] > TELE_LEN:
                    # per-shard plane: this 'data' row's scan volume lands
                    # in its own slot BEFORE the psum — the one-hot
                    # contributions assemble the full plane on every shard,
                    # reusing the collective the named counters already pay
                    scan = inc[TELE_PUSH_EDGES] + inc[TELE_PULL_EDGES]
                    slot = TELE_LEN + jax.lax.axis_index(DATA_AXIS)
                    inc = inc.at[slot].add(scan)
                inc = jax.lax.psum(inc, DATA_AXIS)
                new = new._replace(tele=st.tele + inc)
        return B._policy(program, cfg, n_edges, new)

    return step


def _make_edge_sharded_step(program: ACCProgram, cfg: EngineConfig,
                            n: int, n_edges: int,
                            tele_axes=(DATA_AXIS, MODEL_AXIS)):
    """One edge-shard iteration: scan the shard's COO partition (masked by
    the union frontier for push-semantics programs, unmasked for pull-only
    programs), segment-combine locally, monoid-all-reduce across 'model'.

    No edge budget, no truncation: heavy iterations scan every shard slot
    densely, so push-only programs run without the no-overflow capacity
    assertion and the mode controller degenerates to one scan KIND per
    program. Light iterations of push-semantics programs take the
    **frontier-compacted expansion** (`cfg.shard_compact`, DESIGN.md §11):
    the shard gathers only COO slots whose source is in the union frontier —
    stream-compacted into a bounded `ceil(slots * shard_compact_frac)`
    buffer — instead of paying the full O(m/shards) gather/compute. The
    existing consensus controller is the switch (its PUSH decision == a
    light iteration; pull-only programs always scan densely — every slot
    contributes to an unmasked SpMM), and a compaction-buffer overflow falls
    back to the dense scan for that iteration, so nothing can ever truncate.
    Both scan flavors produce the same contribution multiset per
    destination, so results (and the degenerate mode trace) are
    bit-identical to the always-dense scan — compaction is purely a cost
    switch, which is what lets the two paths share one differential test
    oracle (tests/test_sharded.py).
    """
    comb = program.combiner
    masked = program.modes != "pull"      # push semantics for both/push
    was_mode = PUSH if masked else PULL

    def scan_dense(st, src, dst, w, valid):
        sender = {k: v[src] for k, v in st.m.items()}        # (E_s, Q) rows
        receiver = {k: v[dst] for k, v in st.m.items()}
        upd = program.compute(sender, w[:, None], receiver)
        ident = comb.identity(upd.dtype)
        if masked:
            eactive = st.active[src] & valid[:, None]
        else:
            eactive = jnp.broadcast_to(valid[:, None], upd.shape)
        upd = jnp.where(eactive, upd, ident)
        return comb.segment(upd, dst, n + 1)                 # shard partial

    def scan_compacted(st, src, dst, w, eact, cap):
        # the id compaction (cumsum + scatter) runs only on iterations that
        # actually take this branch; heavy iterations pay one O(E_s) count
        ids, lane_ok, _ovf = F.select_edges(eact, cap)
        ssrc, sdst, sw = src[ids], dst[ids], w[ids]
        sender = {k: v[ssrc] for k, v in st.m.items()}       # (cap, Q) rows
        receiver = {k: v[sdst] for k, v in st.m.items()}
        upd = program.compute(sender, sw[:, None], receiver)
        ident = comb.identity(upd.dtype)
        # selected lanes hold union-frontier edges; per-query masking still
        # applies (an edge carries query q's message iff its source is in
        # q's frontier), and clamped filler lanes are inert
        eactive = st.active[ssrc] & lane_ok[:, None]
        upd = jnp.where(eactive, upd, ident)
        return comb.segment(upd, sdst, n + 1)

    def step(st: B.BatchState, esrc, edst, ewgt, deg,
             dsrc, ddst, dwgt) -> B.BatchState:
        src = esrc.reshape(-1)
        dst = edst.reshape(-1)
        w = ewgt.reshape(-1)
        if dsrc is not None:              # per-shard streaming delta slice
            src = jnp.concatenate([src, dsrc.reshape(-1)])
            dst = jnp.concatenate([dst, ddst.reshape(-1)])
            w = jnp.concatenate([w, dwgt.reshape(-1)])
        valid = (src < n) & (dst < n)     # sentinel pads / neutralized slots

        e_tot = int(src.shape[0])
        tele_inc = (None if st.tele is None
                    else jnp.zeros_like(st.tele))
        if masked and cfg.shard_compact:
            cap = min(e_tot, max(128, int(
                math.ceil(e_tot * cfg.shard_compact_frac))))
            union = jnp.any(st.active, axis=-1)              # (n+1,)
            eact = union[src] & valid
            c_ovf = jnp.sum(eact) > cap                      # O(E_s) count
            # the controller's carried decision: PUSH == light iteration.
            # Shards of one 'model' group see identical lanes, so they take
            # the same branch; the cross-shard all-reduce sits OUTSIDE the
            # cond, so divergent groups (possible when Q also shards over
            # 'data') still meet every collective in lockstep.
            heavy = B._consensus_mode(program, cfg, n_edges, st) == PULL
            seg = jax.lax.cond(
                heavy | c_ovf,
                lambda s: scan_dense(s, src, dst, w, valid),
                lambda s: scan_compacted(s, src, dst, w, eact, cap),
                st,
            )
            if tele_inc is not None:
                light = ~(heavy | c_ovf)                  # compacted branch
                tele_inc = (
                    tele_inc
                    .at[TELE_COMPACT_HITS].add(light.astype(jnp.int32))
                    .at[TELE_COMPACT_DENSE].add(
                        (~heavy & c_ovf).astype(jnp.int32))
                    # buffer lanes gathered vs full shard slots scanned
                    .at[TELE_PUSH_EDGES].add(
                        jnp.where(light, jnp.int32(cap), jnp.int32(e_tot))))
        else:
            seg = scan_dense(st, src, dst, w, valid)
            if tele_inc is not None:
                slot = TELE_PUSH_EDGES if masked else TELE_PULL_EDGES
                tele_inc = tele_inc.at[slot].add(jnp.int32(e_tot))
        seg = _monoid_all_reduce(comb, seg, MODEL_AXIS)      # cross-shard merge
        if tele_inc is not None:
            # each (data, model) shard counted its own slice's work.
            # Host-stepped bodies sum over BOTH axes (every shard steps
            # exactly once per call, and the replicated out-spec needs the
            # mesh-global value); fused-loop bodies sum over 'model' only —
            # data rows exit the while_loop at independent trip counts, so
            # a 'data' collective inside the loop would deadlock, and
            # `_normalize_scalars` globalizes at exit instead.
            # Unconditional collective (sits outside the cond above).
            if tele_inc.shape[0] > TELE_LEN:
                # per-shard plane: this 'model' column's slice volume lands
                # in its own slot before the existing psum — the plane then
                # resolves to per-edge-shard totals (summed over 'data' by
                # the same psum / the exit normalize) at zero extra
                # collectives
                scan = tele_inc[TELE_PUSH_EDGES] + tele_inc[TELE_PULL_EDGES]
                slot = TELE_LEN + jax.lax.axis_index(MODEL_AXIS)
                tele_inc = tele_inc.at[slot].add(scan)
            tele_inc = jax.lax.psum(tele_inc, tele_axes)

        m_new = program.run_apply(st.m, seg, st.it)
        nxt = program.active(m_new, st.m, st.it)
        nxt = nxt.at[-1].set(False)
        nxt = nxt & ~st.done[None, :]
        count = jnp.sum(nxt, axis=0).astype(jnp.int32)
        fe, ovf = B._union_volume_deg(deg, cfg, nxt)
        tele = None if tele_inc is None else st.tele + tele_inc
        new = B._advance(st, m_new, nxt, count, fe, ovf,
                         was_mode=was_mode, cfg=cfg, tele=tele)
        max_it = (program.fixed_iters if program.fixed_iters is not None
                  else cfg.max_iters)
        done = new.done | (new.count == 0) | (new.it >= max_it)
        return new._replace(done=done)

    return step


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ShardedBatchEngine:
    """The batched ACC loop under shard_map on a ('data', 'model') mesh.

    `placement='replicated'` query-shards Q over 'data' with the graph
    replicated; `placement='edge_sharded'` splits the edge list over 'model'
    (queries still shard over 'data' when it is >1). Graph views are traced
    args placed once per `set_graph` — streaming updates swap views without
    recompiling, exactly like the single-device pools.
    """

    def __init__(self, program: ACCProgram, g: Graph, pack: EllPack,
                 cfg: EngineConfig, mesh, *, placement: str = "replicated",
                 consensus: str = "global",
                 delta: Optional[EdgeDelta] = None,
                 telemetry: bool = False):
        assert placement in ("replicated", "edge_sharded"), placement
        assert consensus in ("global", "local"), consensus
        if placement == "edge_sharded":
            assert not cfg.masked_pull, (
                "masked pull's per-slice caches assume a replicated pack")
        assert not (telemetry and consensus == "local"), (
            "telemetry counters are mesh-global (psum'd increments) — "
            "consensus='local' promises NO collectives, so the replicated "
            "accumulator spec cannot hold; run telemetry with "
            "consensus='global'")
        self.telemetry = bool(telemetry)
        self.program = program
        self.cfg = cfg
        self.mesh = mesh
        self.placement = placement
        self.consensus = consensus
        self.n = g.n_nodes
        self.n_edges = g.n_edges
        self.n_query_shards = int(mesh.shape[DATA_AXIS])
        self.n_edge_shards = int(mesh.shape[MODEL_AXIS])
        self._specs = None          # built on first init (needs a template)
        self._shardings = None
        self._step_j = None
        self._run_j = None
        self._rebuild_pending = False
        # diff-shipping caches (touched-delta slice shipping, DESIGN.md §11)
        self._rep_cache: dict = {}      # replicated: name -> (treedef, host, dev)
        self._row_cache: dict = {}      # edge-sharded: name -> (host (S,L), dev)
        self._base_leaves = None
        self._delta_leaves = None
        self.deg = None
        self._deg_base = None
        self.delta = delta              # pre-set for set_graph's delta-ness check
        self.last_ship: dict = {}
        self.set_graph(g, pack, delta)

    # -- device views --------------------------------------------------------

    def set_graph(self, g: Graph, pack: EllPack,
                  delta: Optional[EdgeDelta]) -> None:
        """(Re)place the graph views on the mesh, shipping only what CHANGED
        (DESIGN.md §11 — streaming updates used to re-broadcast every view to
        every replica per batch):

          * replicated placement diffs the new views against the previous
            ones LEAF BY LEAF (the streaming overlay keeps untouched arrays
            identity-stable across `apply` batches) and re-broadcasts only
            the changed leaves — an insert-only batch ships the delta COO +
            the delta ELL slice, never the O(m) CSR arrays;
          * edge-sharded placement re-slices and ships only the per-shard
            COO/delta ROWS whose contents changed (`partition.shard_delta`
            diffed against the previous slices), stitching unchanged shards'
            resident device buffers back into the global view. The O(m)
            adjacency itself never lands on the mesh at all — admission is
            CSR-free and consumes only the cached (n,) live-degree vector.

        Shapes are update-invariant, so pools swap views with no recompile
        (an overflow rebuild changes m and pays one full re-ship + compile,
        as on one device). `last_ship` records what this call moved."""
        if self._specs is not None:
            # the step closures' in_specs were built for this delta-ness;
            # an EdgeDelta appearing/vanishing changes the arg pytree
            assert (delta is None) == (self.delta is None), (
                "set_graph cannot change whether a delta overlay exists — "
                "construct the engine with the (possibly empty) delta")
        if g.n_edges != self.n_edges:
            # an overflow rebuild changed the edge count: the consensus
            # alpha test's denominator (and, for replicated placement, the
            # view-spec pytree) are baked into the step/run closures —
            # refresh them so post-rebuild decisions use the CURRENT m
            # (they pay a retrace anyway: the view shapes moved)
            self.n_edges = g.n_edges
            if self._specs is not None:
                self._rebuild_pending = True
        self.last_ship = {"replicated_leaves_shipped": 0,
                          "replicated_leaves_total": 0,
                          "edge_shards_shipped": 0,
                          "delta_shards_shipped": 0,
                          "n_edge_shards": self.n_edge_shards}
        if self.placement == "replicated":
            self.g = self._put_rep_diff("g", g)
            self.pack = self._put_rep_diff("pack", pack)
            self.delta = (self._put_rep_diff("delta", delta)
                          if delta is not None else None)
            self._maybe_rebuild_jits()
            return
        # edge-sharded: host-side references only (live-degree counting);
        # the replicated CSR/pack never reach the mesh (CSR-free admission)
        self.g, self.pack, self.delta = g, pack, delta
        s_edges = NamedSharding(self.mesh, P(MODEL_AXIS, None))
        rep = NamedSharding(self.mesh, P())
        base_leaves = (g.out.row_ptr, g.out.col_idx, g.out.weights,
                       g.out.src_idx)
        base_changed = (self._base_leaves is None or any(
            a is not b for a, b in zip(base_leaves, self._base_leaves)))
        if base_changed:
            es, ed, ew = partition.shard_edges_np(g, self.n_edge_shards)
            self.esrc, n1 = self._place_rows("esrc", es, s_edges)
            self.edst, n2 = self._place_rows("edst", ed, s_edges)
            self.ewgt, n3 = self._place_rows("ewgt", ew, s_edges)
            self.last_ship["edge_shards_shipped"] = max(n1, n2, n3)
            self._base_leaves = base_leaves
        delta_leaves = (None if delta is None
                        else (delta.src, delta.dst, delta.w))
        delta_changed = delta is not None and (
            self._delta_leaves is None or any(
                a is not b for a, b in zip(delta_leaves, self._delta_leaves)))
        if delta is None:
            self.dsrc = self.ddst = self.dwgt = None
        elif delta_changed:
            if self.n_edge_shards == 1:
                # single shard: the round-robin layout is the identity, so
                # take partition.shard_delta's zero-copy reshape instead of
                # allocating + diffing a resliced host copy per update
                dsh = partition.shard_delta(delta, 1, self.n)
                self.dsrc = jax.device_put(dsh.src, s_edges)
                self.ddst = jax.device_put(dsh.dst, s_edges)
                self.dwgt = jax.device_put(dsh.w, s_edges)
                self.last_ship["delta_shards_shipped"] = 1
            else:
                ds, dd, dw = partition.shard_delta_np(
                    delta, self.n_edge_shards, self.n)
                self.dsrc, k1 = self._place_rows("dsrc", ds, s_edges)
                self.ddst, k2 = self._place_rows("ddst", dd, s_edges)
                self.dwgt, k3 = self._place_rows("dwgt", dw, s_edges)
                self.last_ship["delta_shards_shipped"] = max(k1, k2, k3)
            self._delta_leaves = delta_leaves
        if base_changed or self._deg_base is None:
            self._deg_base = live_degrees(g.out, None)     # O(m), per version
        if base_changed or delta_changed or self.deg is None:
            deg = self._deg_base
            if delta is not None:
                # integer adds decompose exactly: base count + O(cap) delta
                # lanes — insert-only updates never pay the O(m) recount
                deg = deg.at[delta.src].add(
                    (delta.src < self.n).astype(jnp.int32), mode="drop")
            self.deg = jax.device_put(deg, rep)
        self._maybe_rebuild_jits()

    def _maybe_rebuild_jits(self) -> None:
        """Re-close the jitted step/run over the refreshed static dims (and,
        for replicated placement, the current views' spec pytree) after an
        overflow rebuild changed the edge count."""
        if self._rebuild_pending and self._specs is not None:
            self._rebuild_pending = False
            self._build_jits()

    # -- diff shipping helpers ----------------------------------------------

    def _put_rep_diff(self, name: str, tree):
        """Broadcast `tree` to every shard, reusing the resident replica for
        every leaf that is the SAME array object as last time (the streaming
        overlay's identity-stability contract, streaming/delta.py). A
        structure change (an overflow rebuild re-buckets the ELL pack)
        re-ships everything."""
        rep = NamedSharding(self.mesh, P())
        leaves, treedef = jax.tree.flatten(tree)
        prev = self._rep_cache.get(name)
        self.last_ship["replicated_leaves_total"] += len(leaves)
        if prev is not None and prev[0] == treedef:
            _, old_leaves, old_dev = prev
            dev_leaves = []
            for nl, ol, dl in zip(leaves, old_leaves, old_dev):
                if nl is ol:
                    dev_leaves.append(dl)
                else:
                    self.last_ship["replicated_leaves_shipped"] += 1
                    dev_leaves.append(jax.device_put(nl, rep))
        else:
            self.last_ship["replicated_leaves_shipped"] += len(leaves)
            dev_leaves = [jax.device_put(l, rep) for l in leaves]
        self._rep_cache[name] = (treedef, leaves, dev_leaves)
        return jax.tree.unflatten(treedef, dev_leaves)

    def _place_rows(self, name: str, new_host: np.ndarray, sharding):
        """Place an (S, L) row-sharded view, shipping only the rows whose
        contents differ from the cached previous host slices; unchanged rows
        keep their resident per-device buffers, stitched back into the
        global view with `jax.make_array_from_single_device_arrays`.
        Returns (global array, rows shipped)."""
        prev = self._row_cache.get(name)
        s = new_host.shape[0]
        if prev is None or prev[0].shape != new_host.shape:
            dev = jax.device_put(jnp.asarray(new_host), sharding)
            shipped = s
        else:
            old_host, old_dev = prev
            changed = {r for r in range(s)
                       if not np.array_equal(new_host[r], old_host[r])}
            if not changed:
                dev, shipped = old_dev, 0
            else:
                parts = []
                for sh in old_dev.addressable_shards:
                    r = sh.index[0].start or 0
                    parts.append(
                        jax.device_put(new_host[r:r + 1], sh.device)
                        if r in changed else sh.data)
                dev = jax.make_array_from_single_device_arrays(
                    new_host.shape, old_dev.sharding, parts)
                shipped = len(changed)
        self._row_cache[name] = (new_host, dev)
        return dev, shipped

    def _views(self) -> tuple:
        if self.placement == "replicated":
            return (self.g, self.pack, self.delta)
        return (self.esrc, self.edst, self.ewgt, self.deg,
                self.dsrc, self.ddst, self.dwgt)

    # -- state construction --------------------------------------------------

    def init(self, sources, done=None) -> B.BatchState:
        """Sharded initial state for Q = len(sources) lanes (Q must divide by
        the 'data' axis). `init_batch` computes the GLOBAL consensus inputs
        before the state is scattered, so iteration 0's decision is already
        the single-device one. Edge-sharded engines init CSR-FREE: only the
        static graph dims and the cached (n,) live-degree vector enter the
        computation (DESIGN.md §11) — never the O(m) adjacency arrays."""
        sources = jnp.asarray(sources, jnp.int32)
        q = int(sources.shape[0])
        assert q % self.n_query_shards == 0, (q, self.n_query_shards)
        if self.placement == "edge_sharded":
            st = B.init_batch(self.program,
                              B.GraphDims(self.n, self.n_edges), self.cfg,
                              sources, done=done, check_caps=False,
                              deg=self.deg, telemetry=self.telemetry,
                              tele_shards=self.n_edge_shards)
        else:
            pack = self.pack if self.cfg.masked_pull else None
            st = B.init_batch(self.program, self.g, self.cfg, sources,
                              done=done, pack=pack, delta=self.delta,
                              telemetry=self.telemetry,
                              tele_shards=self.n_query_shards)
        if self._specs is None:
            self._build(st)
        return jax.device_put(st, self._shardings)

    def _build(self, st: B.BatchState) -> None:
        self._specs = state_specs(st, self.mesh)
        self._shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._specs,
            is_leaf=_SPEC_LEAF)
        self._build_jits()

    def _build_jits(self) -> None:
        if self.placement == "replicated":
            view_specs = (
                _replicated_specs(self.g),
                _replicated_specs(self.pack),
                _replicated_specs(self.delta) if self.delta is not None
                else None,
            )
            body = _make_replicated_step(
                self.program, self.cfg, self.n_edges, self.consensus)
        else:
            es = P(MODEL_AXIS, None)
            dspec = es if self.dsrc is not None else None
            view_specs = (es, es, es, P(), dspec, dspec, dspec)
            body = _make_edge_sharded_step(
                self.program, self.cfg, self.n, self.n_edges)
        self._step_j = jax.jit(compat.shard_map(
            body, mesh=self.mesh, in_specs=(self._specs,) + view_specs,
            out_specs=self._specs))
        if self.placement == "edge_sharded":
            # the fused loop needs a 'data'-collective-free body (rows run
            # independent trip counts) — tele sums over 'model' in-loop and
            # over 'data' at exit (_normalize_scalars)
            run_body = _make_edge_sharded_step(
                self.program, self.cfg, self.n, self.n_edges,
                tele_axes=(MODEL_AXIS,))
        else:
            run_body = body
        self._run_j = jax.jit(compat.shard_map(
            self._make_run(run_body), mesh=self.mesh,
            in_specs=(self._specs,) + view_specs, out_specs=self._specs))

    def _make_run(self, body):
        """Fused convergence loop around the per-shard step.

        Global consensus carries the psum'd live count so every shard runs
        the same trip count (required: the body contains collectives) and the
        iteration schedule matches the single-device fused loop. Local
        consensus / edge shards loop on shard-local liveness — edge-shard
        rows are bitwise-identical within a 'model' group, so their psums
        stay in lockstep without a carried global.
        """
        placement, consensus = self.placement, self.consensus

        def run(st, *views):
            if placement == "replicated" and consensus == "global":
                def cond(c):
                    return c[1] > 0

                def it(c):
                    s = body(c[0], *views)
                    return s, _live_count(s, (DATA_AXIS,))

                st, _ = jax.lax.while_loop(
                    cond, it, (st, _live_count(st, (DATA_AXIS,))))
                return st
            st = jax.lax.while_loop(
                lambda s: jnp.any(~s.done), lambda s: body(s, *views), st)
            return _normalize_scalars(st, (DATA_AXIS, MODEL_AXIS))

        return run

    # -- execution -----------------------------------------------------------

    def step(self, st: B.BatchState) -> B.BatchState:
        """One batched iteration across every shard (the scheduler's
        host-stepped path). Requires the global controller — per-shard local
        decisions would leave the carried consensus scalars shard-local."""
        assert self.consensus == "global" or self.placement == "edge_sharded"
        return self._step_j(st, *self._views())

    def run(self, st: B.BatchState):
        """Advance `st` to convergence; returns (metadata, stats)."""
        final = self._run_j(st, *self._views())
        stats = {
            "iterations": jnp.max(final.it),
            "per_query_iters": final.it,
            "push_iters": final.push_iters,
            "pull_iters": final.pull_iters,
            "switches": final.switches,
            "final_count": final.count,
            "mode_trace": final.mode_trace,
            "tele": final.tele,
        }
        return final.m, stats

    @property
    def state_shardings(self):
        assert self._shardings is not None, "call init() first"
        return self._shardings


def run_sharded(program: ACCProgram, g: Graph, pack: EllPack,
                cfg: EngineConfig, mesh, sources, *,
                placement: str = "replicated", consensus: str = "global",
                delta: Optional[EdgeDelta] = None):
    """`run_batch`, sharded: Q point queries to convergence on `mesh`.
    Returns (metadata dict — field -> global (n+1, Q) —, stats)."""
    eng = ShardedBatchEngine(program, g, pack, cfg, mesh,
                             placement=placement, consensus=consensus,
                             delta=delta)
    st0 = eng.init(sources)
    return eng.run(st0)


def shard_sources(sources, n_shards: int) -> list:
    """The per-shard source slices a ('data'=n_shards) mesh assigns: shard d
    owns the contiguous block sources[d*Q/D : (d+1)*Q/D] (jax shards the
    trailing Q axis in contiguous blocks)."""
    sources = np.asarray(sources)
    q = sources.shape[0]
    assert q % n_shards == 0, (q, n_shards)
    per = q // n_shards
    return [sources[d * per:(d + 1) * per] for d in range(n_shards)]
