"""Sharded multi-device batched serving engine (DESIGN.md §9).

The batched vertex-major engine (`serving/batch_engine.py`) runs Q point
queries in one fused loop on ONE device. This module lifts that loop onto a
('data', 'model') device mesh with `shard_map`, along the two scaling axes
the repo already has layouts for (Gunrock's multi-GPU split, GraphBLAST's
SpMM view):

  * **query-sharded** (`placement='replicated'`): queries are embarrassingly
    parallel, so the Q axis splits over the 'data' mesh axis and the
    graph/pack/delta views replicate. Each shard runs the unmodified batched
    push/pull iteration on its Q/D lanes; the only cross-shard state is the
    JIT controller's input: per-shard union masks are `psum`-reduced over
    'data' into the exact global union, so the one scalar push/pull decision
    per iteration is a pure function of the same volumes the single-device
    consensus controller sees — the global mode sequence (and hence the mode
    trace) is identical to the single-device batched engine's.

  * **edge-partitioned** (`placement='edge_sharded'`): for graphs whose edge
    set outgrows one device, `graph/partition.py`'s 1-D edge shards split
    over the 'model' axis while metadata replicates within each mesh row.
    Each shard scans ITS edge partition per iteration (frontier-masked for
    push-semantics programs, unmasked for pull-only programs — the SpMM
    formulation), segment-combines locally into an (n+1, Q) partial, and the
    partials merge across shards with the combine monoid's all-reduce
    (`psum` for sum — implementable as psum_scatter+all_gather — and
    pmin/pmax for the idempotent monoids). Per-iteration device state
    touches only the shard's E/S edge triples + O(n·Q) metadata.

Exactness (§7 argument, unchanged): per-query metadata is a pure function
of per-query frontier trajectories; batch-mates and shard layout influence
only the mode sequence, and for idempotent min/max programs a push and a
pull iteration produce bit-identical metadata. Query-sharded results are
therefore bit-identical to the single-device batched engine for the whole
served suite (pull-only sum programs trivially so: identical iteration
structure, pinned reduction trees). Edge-partitioned results are bit-exact
for min/max programs (min/max are reassociation-free across the shard
merge); sum programs see one extra reassociation (the cross-shard psum) and
match to FP tolerance.

Consensus flavors:

  * `consensus='global'` (default): the psum'd controller above. Shards run
    in lockstep (the fused loop carries the psum'd live count so every shard
    exits the `while_loop` on the same trip); the mode trace equals the
    single-device trace (tests/test_sharded.py pins this on RMAT-12).
  * `consensus='local'`: each shard decides modes from its own union — NO
    collectives at all in replicated placement, so shards converge fully
    independently (results still bit-identical by idempotence; mode traces
    may diverge per shard — the regression test demonstrates the divergence
    the psum reduction exists to prevent). Fused runs only.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.acc import ACCProgram, Combiner
from repro.core.engine import PULL, PUSH, EngineConfig
from repro.graph import partition
from repro.graph.csr import EdgeDelta, Graph, live_degrees
from repro.graph.packing import EllPack
from repro.serving import batch_engine as B

DATA_AXIS = "data"     # query shards
MODEL_AXIS = "model"   # edge shards

_SPEC_LEAF = lambda x: isinstance(x, P) or x is None  # noqa: E731


def make_serving_mesh(n_query_shards: int = 1, n_edge_shards: int = 1):
    """('data', 'model') mesh for sharded pools. Needs
    `n_query_shards * n_edge_shards` jax devices (force host devices with
    XLA_FLAGS=--xla_force_host_platform_device_count=N for CPU meshes)."""
    devs = jax.devices()
    need = n_query_shards * n_edge_shards
    if len(devs) < need:
        raise RuntimeError(
            f"mesh ({n_query_shards}, {n_edge_shards}) needs {need} devices, "
            f"have {len(devs)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return compat.make_mesh(
        (n_query_shards, n_edge_shards), (DATA_AXIS, MODEL_AXIS),
        devices=devs[:need],
        axis_types=(compat.AxisType.Auto, compat.AxisType.Auto),
    )


def state_specs(st: B.BatchState, mesh=None) -> B.BatchState:
    """PartitionSpec tree for a BatchState: Q axis over 'data', vertex axis
    and consensus scalars replicated (the global controller keeps the
    scalars bitwise-equal across shards). With a mesh, the specs come from
    the logical-axis layer (`distributed/sharding.py`'s 'queries' rule), so
    the state layout collapses gracefully on meshes without a 'data' axis."""
    if mesh is not None:
        from repro.distributed import sharding as SH

        with SH.activate(mesh):
            qv = SH.spec(None, "queries")   # (n+1, Q) vertex-major
            ql = SH.spec("queries")         # (Q,) per-lane
            tr = SH.spec("queries", None)   # (Q, trace_len)
    else:
        qv, ql, tr = P(None, DATA_AXIS), P(DATA_AXIS), P(DATA_AXIS, None)
    return B.BatchState(
        m={k: qv for k in st.m},
        active=qv, count=ql, union_fe=P(), overflow=P(),
        mode=ql, it=ql, done=ql,
        push_iters=ql, pull_iters=ql, switches=ql,
        mode_trace=tr, gmode=P(),
        pseg=tuple(qv for _ in st.pseg),
        pull_dense=None if st.pull_dense is None else P(),
        hot=None if st.hot is None else qv,
    )


def _replicated_specs(tree):
    return jax.tree.map(lambda _: P(), tree)


def _monoid_all_reduce(comb: Combiner, x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """All-reduce `x` across `axis` in the combine monoid. The idempotent
    monoids use pmin/pmax (reassociation-free -> bit-exact merge); sum uses
    psum (the psum_scatter + all_gather decomposition when XLA tiles it)."""
    if comb.name == "sum":
        return jax.lax.psum(x, axis)
    if comb.name == "min":
        return jax.lax.pmin(x, axis)
    if comb.name == "max":
        return jax.lax.pmax(x, axis)
    raise ValueError(comb.name)


def _global_union_volume(deg, cfg, mask, axis):
    """The single-device controller's (union_fe, overflow) reconstructed
    exactly across query shards: psum the per-shard union masks (union of
    unions, NOT a sum of volumes — overlapping frontiers must not double
    count), then measure the global union's out-edge volume."""
    local = jnp.any(mask, axis=-1).astype(jnp.int32)      # (n+1,)
    union = jax.lax.psum(local, axis) > 0
    fe = jnp.sum(jnp.where(union[:-1], deg, 0)).astype(jnp.int32)
    ucount = jnp.sum(union[:-1]).astype(jnp.int32)
    return fe, ucount > cfg.frontier_cap


def _live_count(st, axes) -> jnp.ndarray:
    live = jnp.sum(~st.done).astype(jnp.int32)
    for ax in axes:
        live = jax.lax.psum(live, ax)
    return live


def _normalize_scalars(st, comb_gmode_axes):
    """Deterministic consensus scalars at loop exit for flavors whose shards
    carry shard-local values (local consensus / per-row edge shards):
    aggregate volume, any-overflow, max mode — replicated by construction so
    the P() out_specs hold."""
    fe = jax.lax.psum(st.union_fe, comb_gmode_axes)
    ovf = jax.lax.psum(st.overflow.astype(jnp.int32), comb_gmode_axes) > 0
    gmode = jax.lax.pmax(st.gmode, comb_gmode_axes)
    return st._replace(union_fe=fe, overflow=ovf, gmode=gmode)


# ---------------------------------------------------------------------------
# per-shard step bodies
# ---------------------------------------------------------------------------


def _make_replicated_step(program: ACCProgram, cfg: EngineConfig,
                          n_edges: int, consensus: str):
    """One query-shard iteration: the unmodified single-device batched step
    on the shard's lanes, with the controller inputs globalized by psum when
    `consensus='global'`."""

    def step(st: B.BatchState, g: Graph, pack: EllPack,
             delta: Optional[EdgeDelta]) -> B.BatchState:
        if program.modes == "push":
            new = B._push_step(program, g.out, cfg, st, delta)
        elif program.modes == "pull":
            new = B._pull_step(program, pack, cfg, st, g.out)
        else:
            new = jax.lax.cond(
                st.gmode == PULL,
                lambda s: B._pull_step(program, pack, cfg, s, g.out),
                lambda s: B._push_step(program, g.out, cfg, s, delta),
                st,
            )
        if consensus == "global":
            # the psum sits OUTSIDE the push/pull cond: every shard executes
            # it unconditionally, so the collective schedule is uniform
            deg = g.out.row_ptr[1:] - g.out.row_ptr[:-1]
            fe, ovf = _global_union_volume(deg, cfg, new.active, DATA_AXIS)
            new = new._replace(union_fe=fe, overflow=ovf)
        return B._policy(program, cfg, n_edges, new)

    return step


def _make_edge_sharded_step(program: ACCProgram, cfg: EngineConfig,
                            n: int, n_edges: int):
    """One edge-shard iteration: scan the shard's COO partition (masked by
    the union frontier for push-semantics programs, unmasked for pull-only
    programs), segment-combine locally, monoid-all-reduce across 'model'.

    No frontier compaction, no edge budget, no overflow: the scan covers
    every shard edge each iteration, so nothing can truncate — push-only
    programs run without the no-overflow capacity assertion, and the mode
    controller degenerates (one scan kind per program).
    """
    comb = program.combiner
    masked = program.modes != "pull"      # push semantics for both/push
    was_mode = PUSH if masked else PULL

    def step(st: B.BatchState, esrc, edst, ewgt, deg,
             dsrc, ddst, dwgt) -> B.BatchState:
        src = esrc.reshape(-1)
        dst = edst.reshape(-1)
        w = ewgt.reshape(-1)
        if dsrc is not None:              # per-shard streaming delta slice
            src = jnp.concatenate([src, dsrc.reshape(-1)])
            dst = jnp.concatenate([dst, ddst.reshape(-1)])
            w = jnp.concatenate([w, dwgt.reshape(-1)])
        valid = (src < n) & (dst < n)     # sentinel pads / neutralized slots

        sender = {k: v[src] for k, v in st.m.items()}        # (E_s, Q) rows
        receiver = {k: v[dst] for k, v in st.m.items()}
        upd = program.compute(sender, w[:, None], receiver)
        ident = comb.identity(upd.dtype)
        if masked:
            eactive = st.active[src] & valid[:, None]
        else:
            eactive = jnp.broadcast_to(valid[:, None], upd.shape)
        upd = jnp.where(eactive, upd, ident)
        seg = comb.segment(upd, dst, n + 1)                  # shard partial
        seg = _monoid_all_reduce(comb, seg, MODEL_AXIS)      # cross-shard merge

        m_new = program.run_apply(st.m, seg, st.it)
        nxt = program.active(m_new, st.m, st.it)
        nxt = nxt.at[-1].set(False)
        nxt = nxt & ~st.done[None, :]
        count = jnp.sum(nxt, axis=0).astype(jnp.int32)
        fe, ovf = B._union_volume_deg(deg, cfg, nxt)
        new = B._advance(st, m_new, nxt, count, fe, ovf,
                         was_mode=was_mode, cfg=cfg)
        max_it = (program.fixed_iters if program.fixed_iters is not None
                  else cfg.max_iters)
        done = new.done | (new.count == 0) | (new.it >= max_it)
        return new._replace(done=done)

    return step


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ShardedBatchEngine:
    """The batched ACC loop under shard_map on a ('data', 'model') mesh.

    `placement='replicated'` query-shards Q over 'data' with the graph
    replicated; `placement='edge_sharded'` splits the edge list over 'model'
    (queries still shard over 'data' when it is >1). Graph views are traced
    args placed once per `set_graph` — streaming updates swap views without
    recompiling, exactly like the single-device pools.
    """

    def __init__(self, program: ACCProgram, g: Graph, pack: EllPack,
                 cfg: EngineConfig, mesh, *, placement: str = "replicated",
                 consensus: str = "global",
                 delta: Optional[EdgeDelta] = None):
        assert placement in ("replicated", "edge_sharded"), placement
        assert consensus in ("global", "local"), consensus
        if placement == "edge_sharded":
            assert not cfg.masked_pull, (
                "masked pull's per-slice caches assume a replicated pack")
        self.program = program
        self.cfg = cfg
        self.mesh = mesh
        self.placement = placement
        self.consensus = consensus
        self.n = g.n_nodes
        self.n_edges = g.n_edges
        self.n_query_shards = int(mesh.shape[DATA_AXIS])
        self.n_edge_shards = int(mesh.shape[MODEL_AXIS])
        self._specs = None          # built on first init (needs a template)
        self._shardings = None
        self._step_j = None
        self._run_j = None
        self.set_graph(g, pack, delta)

    # -- device views --------------------------------------------------------

    def set_graph(self, g: Graph, pack: EllPack,
                  delta: Optional[EdgeDelta]) -> None:
        """(Re)place the graph views on the mesh. Replicated placement
        broadcasts all three views to every shard; edge-sharded placement
        re-partitions the (possibly overlay-neutralized) edge list over
        'model' and round-robins the insertion delta into per-shard slices.
        Shapes are update-invariant, so pools swap views with no recompile
        (an overflow rebuild changes m and pays one, as on one device)."""
        if self._specs is not None:
            # the step closures' in_specs were built for this delta-ness;
            # an EdgeDelta appearing/vanishing changes the arg pytree
            assert (delta is None) == (self.delta is None), (
                "set_graph cannot change whether a delta overlay exists — "
                "construct the engine with the (possibly empty) delta")
        rep = NamedSharding(self.mesh, P())
        put_rep = lambda t: jax.tree.map(  # noqa: E731
            lambda x: jax.device_put(x, rep), t)
        self.g = put_rep(g)
        self.pack = put_rep(pack)
        self.delta = put_rep(delta) if delta is not None else None
        if self.placement == "edge_sharded":
            esh = partition.shard_edges(g, self.n_edge_shards)
            s_edges = NamedSharding(self.mesh, P(MODEL_AXIS, None))
            self.esrc = jax.device_put(esh.src, s_edges)
            self.edst = jax.device_put(esh.dst, s_edges)
            self.ewgt = jax.device_put(esh.wgt, s_edges)
            self.deg = jax.device_put(live_degrees(g.out, delta), rep)
            if delta is not None:
                dsh = partition.shard_delta(delta, self.n_edge_shards, self.n)
                self.dsrc = jax.device_put(dsh.src, s_edges)
                self.ddst = jax.device_put(dsh.dst, s_edges)
                self.dwgt = jax.device_put(dsh.w, s_edges)
            else:
                self.dsrc = self.ddst = self.dwgt = None

    def _views(self) -> tuple:
        if self.placement == "replicated":
            return (self.g, self.pack, self.delta)
        return (self.esrc, self.edst, self.ewgt, self.deg,
                self.dsrc, self.ddst, self.dwgt)

    # -- state construction --------------------------------------------------

    def init(self, sources, done=None) -> B.BatchState:
        """Sharded initial state for Q = len(sources) lanes (Q must divide by
        the 'data' axis). `init_batch` computes the GLOBAL consensus inputs
        before the state is scattered, so iteration 0's decision is already
        the single-device one."""
        sources = jnp.asarray(sources, jnp.int32)
        q = int(sources.shape[0])
        assert q % self.n_query_shards == 0, (q, self.n_query_shards)
        pack = self.pack if self.cfg.masked_pull else None
        st = B.init_batch(self.program, self.g, self.cfg, sources,
                          done=done, pack=pack,
                          check_caps=self.placement != "edge_sharded",
                          delta=self.delta)
        if self._specs is None:
            self._build(st)
        return jax.device_put(st, self._shardings)

    def _build(self, st: B.BatchState) -> None:
        self._specs = state_specs(st, self.mesh)
        self._shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self._specs,
            is_leaf=_SPEC_LEAF)
        if self.placement == "replicated":
            view_specs = (
                _replicated_specs(self.g),
                _replicated_specs(self.pack),
                _replicated_specs(self.delta) if self.delta is not None
                else None,
            )
            body = _make_replicated_step(
                self.program, self.cfg, self.n_edges, self.consensus)
        else:
            es = P(MODEL_AXIS, None)
            dspec = es if self.dsrc is not None else None
            view_specs = (es, es, es, P(), dspec, dspec, dspec)
            body = _make_edge_sharded_step(
                self.program, self.cfg, self.n, self.n_edges)
        self._step_j = jax.jit(compat.shard_map(
            body, mesh=self.mesh, in_specs=(self._specs,) + view_specs,
            out_specs=self._specs))
        self._run_j = jax.jit(compat.shard_map(
            self._make_run(body), mesh=self.mesh,
            in_specs=(self._specs,) + view_specs, out_specs=self._specs))

    def _make_run(self, body):
        """Fused convergence loop around the per-shard step.

        Global consensus carries the psum'd live count so every shard runs
        the same trip count (required: the body contains collectives) and the
        iteration schedule matches the single-device fused loop. Local
        consensus / edge shards loop on shard-local liveness — edge-shard
        rows are bitwise-identical within a 'model' group, so their psums
        stay in lockstep without a carried global.
        """
        placement, consensus = self.placement, self.consensus

        def run(st, *views):
            if placement == "replicated" and consensus == "global":
                def cond(c):
                    return c[1] > 0

                def it(c):
                    s = body(c[0], *views)
                    return s, _live_count(s, (DATA_AXIS,))

                st, _ = jax.lax.while_loop(
                    cond, it, (st, _live_count(st, (DATA_AXIS,))))
                return st
            st = jax.lax.while_loop(
                lambda s: jnp.any(~s.done), lambda s: body(s, *views), st)
            return _normalize_scalars(st, (DATA_AXIS, MODEL_AXIS))

        return run

    # -- execution -----------------------------------------------------------

    def step(self, st: B.BatchState) -> B.BatchState:
        """One batched iteration across every shard (the scheduler's
        host-stepped path). Requires the global controller — per-shard local
        decisions would leave the carried consensus scalars shard-local."""
        assert self.consensus == "global" or self.placement == "edge_sharded"
        return self._step_j(st, *self._views())

    def run(self, st: B.BatchState):
        """Advance `st` to convergence; returns (metadata, stats)."""
        final = self._run_j(st, *self._views())
        stats = {
            "iterations": jnp.max(final.it),
            "per_query_iters": final.it,
            "push_iters": final.push_iters,
            "pull_iters": final.pull_iters,
            "switches": final.switches,
            "final_count": final.count,
            "mode_trace": final.mode_trace,
        }
        return final.m, stats

    @property
    def state_shardings(self):
        assert self._shardings is not None, "call init() first"
        return self._shardings


def run_sharded(program: ACCProgram, g: Graph, pack: EllPack,
                cfg: EngineConfig, mesh, sources, *,
                placement: str = "replicated", consensus: str = "global",
                delta: Optional[EdgeDelta] = None):
    """`run_batch`, sharded: Q point queries to convergence on `mesh`.
    Returns (metadata dict — field -> global (n+1, Q) —, stats)."""
    eng = ShardedBatchEngine(program, g, pack, cfg, mesh,
                             placement=placement, consensus=consensus,
                             delta=delta)
    st0 = eng.init(sources)
    return eng.run(st0)


def shard_sources(sources, n_shards: int) -> list:
    """The per-shard source slices a ('data'=n_shards) mesh assigns: shard d
    owns the contiguous block sources[d*Q/D : (d+1)*Q/D] (jax shards the
    trailing Q axis in contiguous blocks)."""
    sources = np.asarray(sources)
    q = sources.shape[0]
    assert q % n_shards == 0, (q, n_shards)
    per = q // n_shards
    return [sources[d * per:(d + 1) * per] for d in range(n_shards)]
