"""Batched multi-query ACC engine: Q independent point queries, one fused loop.

The single-query engine (`core/engine.py`) runs ONE frontier through a
`lax.while_loop`. Serving traffic is many concurrent point queries (BFS/SSSP
from arbitrary sources, per-user PPR) against a SHARED graph. This module
stacks Q query states and advances all of them in one fused push-pull loop —
SIMD-X's JIT task management lifted from vertices to queries, in the
multi-source masked-SpMV/SpMM formulation of GraphBLAST (arXiv 1908.01407)
and the batched-traversal spirit of Gunrock (arXiv 1701.01170).

Layout is **vertex-major**: metadata fields are (n+1, Q) with the query axis
LAST, and the per-query frontier is a dense (n+1, Q) boolean mask. That
choice is what makes batching pay on real hardware (DESIGN.md §7):

  * Every graph-indexed gather (`m[nbr]`, `m[src]`) pulls CONTIGUOUS
    Q-vectors per vertex — one shared index stream serves all queries, so
    the irregular-access cost of a traversal is amortized Q ways instead of
    being repeated per query (this is exactly SpMV -> SpMM).
  * Segment combines run over the LEADING axis with (E, Q) payloads — the
    native `jax.ops.segment_*` path, one wide scatter; a query-major layout
    would need vmapped scatters, which XLA serializes.
  * **Union push**: in push mode the frontiers of all live queries are
    OR-ed, compacted ONCE with the unbatched online/ballot machinery, and
    expanded ONCE; per-edge updates are masked per query. JIT task
    management happens on the union, amortized across the batch.
  * **Consensus JIT controller**: one scalar push/pull decision per
    iteration from the aggregate union-frontier volume (paper Fig. 7 over
    the whole batch) — `lax.cond` on a batched predicate would execute both
    branches.
  * **Done-masking**: converged queries contribute nothing (their mask
    lanes are False and their metadata is frozen) instead of blocking the
    batch; the scheduler recycles their lanes mid-flight.

Exactness: for idempotent min/max programs (BFS, SSSP, WCC) a push and a
pull iteration compute identical metadata — every contribution is either
pushed when its sender changes or pulled from an already-final value, and
min/max are reassociation-free — so per-query results are bit-identical to
a solo `core.engine.run` even when the consensus mode sequence differs from
the solo policy's. Pull-only programs (PageRank, PPR) keep an identical
iteration structure by construction. Non-idempotent sum programs under
`modes='both'` match up to FP reassociation across modes.

Supported programs: `init` must accept a per-query `source=` kwarg (BFS,
SSSP, PPR) or be source-free, and `apply`/`active` must be elementwise in
the vertex axis (true for the whole paper suite except BP's iteration-count
`active`).
"""

from __future__ import annotations

import functools
import inspect
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import frontier as F
from repro.core.acc import ACCProgram
from repro.core.engine import PULL, PUSH, EngineConfig, expand_frontier
from repro.graph.csr import CSR, EdgeDelta, Graph, live_degrees
from repro.graph.packing import EllPack
from repro.obs import (
    TELE_LEN,
    TELE_MASKED_DENSE,
    TELE_MASKED_ROWS,
    TELE_PULL_EDGES,
    TELE_PUSH_EDGES,
)


class GraphDims(NamedTuple):
    """Static graph dimensions, standing in for a full :class:`Graph` on the
    CSR-free admission path (DESIGN.md §11): edge-partitioned pools never
    scan a replicated CSR, so their `init`/`_admit_lane` calls pass these
    dims plus the pool's cached (n,) live-degree vector instead of shipping
    the O(m) adjacency arrays into every admission."""

    n_nodes: int
    n_edges: int


class BatchState(NamedTuple):
    """Q stacked query states, vertex-major, plus one consensus mode."""

    m: dict                        # {field: (n+1, Q)}
    active: jnp.ndarray            # (n+1, Q) bool — frontier mask, scratch row False
    count: jnp.ndarray             # (Q,) int32 — per-query frontier size
    union_fe: jnp.ndarray          # () int32 — union-frontier out-edge volume
    overflow: jnp.ndarray          # () bool — union compaction overflowed
    mode: jnp.ndarray              # (Q,) int32 — mode each live lane last ran
    it: jnp.ndarray                # (Q,) int32
    done: jnp.ndarray              # (Q,) bool
    push_iters: jnp.ndarray        # (Q,) int32
    pull_iters: jnp.ndarray        # (Q,) int32
    switches: jnp.ndarray          # (Q,) int32
    mode_trace: jnp.ndarray        # (Q, trace_len) int8
    gmode: jnp.ndarray             # () int32 consensus PUSH/PULL
    #: masked-pull partial cache (cfg.masked_pull only): one (R_s, Q) array
    #: per ELL slice holding the slice's last computed row partials.
    pseg: tuple = ()
    #: () bool — next pull must run dense (init / admission / after a push
    #: invalidated the partial cache). None when masked pull is off.
    pull_dense: Optional[jnp.ndarray] = None
    #: (n+1, Q) bool — senders whose PRIMARY changed last iteration, the
    #: exact staleness set for the masked-pull partial cache. Carried only
    #: for residual-push programs (cfg.masked_pull + params kind='residual'),
    #: whose frontier does NOT cover every primary change (a vertex that
    #: absorbs its residual leaves the frontier while its `send` drops to
    #: zero) — with it the masked pull is BIT-IDENTICAL to the dense pull,
    #: not tol-bounded (DESIGN.md §10). None otherwise: min/max programs'
    #: frontiers already capture every change, and the tol-thresholded pull
    #: programs (ppr/pagerank) keep the documented frozen-drift semantics.
    hot: Optional[jnp.ndarray] = None
    #: (TELE_LEN + n_shards,) int32 — cumulative engine telemetry counters
    #: (edges scanned per direction, masked-pull / shard-compaction fallback
    #: events; layout in repro/obs/__init__.py) followed by the per-shard
    #: scan-volume plane (cumulative edges scanned by each shard; one slot
    #: on a single device). None when telemetry is off
    #: (`init_batch(telemetry=False)`, the default): the loop then carries
    #: no extra state and executes no extra ops — the telemetry-disabled
    #: overhead guard in tests/test_obs.py pins this.
    tele: Optional[jnp.ndarray] = None


def _ident(program: ACCProgram, m: dict):
    return program.combiner.identity(m[program.primary].dtype)


def _accepts_source(program: ACCProgram) -> bool:
    """Whether `program.init` takes a per-query `source=` kwarg."""
    params = inspect.signature(program.init).parameters
    return "source" in params or any(
        p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()
    )


def _apply_and_refilter(program, cfg, csr, st, seg):
    """Shared tail of a push/pull iteration: apply the combined updates, take
    the dense changed-mask as the next frontier (ballot semantics — the set a
    solo run's online/ballot filter would produce), and re-aggregate volumes."""
    m_new = program.run_apply(st.m, seg, st.it)
    nxt = program.active(m_new, st.m, st.it)
    nxt = nxt.at[-1].set(False)                      # scratch row stays inert
    nxt = nxt & ~st.done[None, :]                    # done lanes push nothing
    count = jnp.sum(nxt, axis=0).astype(jnp.int32)
    union_fe, overflow = _union_volume(csr, cfg, nxt)
    hot = None
    if st.hot is not None:
        # exact masked-pull staleness: a cached row partial goes stale iff a
        # gathered sender's primary changed this iteration (done lanes are
        # frozen by _advance, so they cannot change)
        hot = (m_new[program.primary] != st.m[program.primary]) \
            & ~st.done[None, :]
    return m_new, nxt, count, union_fe, overflow, hot


def _union_volume_deg(deg: jnp.ndarray, cfg: EngineConfig, mask: jnp.ndarray):
    """`_union_volume` from a bare (n,) out-degree vector — the form shared
    with the sharded engines, which carry degrees instead of a full CSR."""
    union = jnp.any(mask, axis=-1)                   # (n+1,)
    fe = jnp.sum(jnp.where(union[:-1], deg, 0)).astype(jnp.int32)
    ucount = jnp.sum(union[:-1]).astype(jnp.int32)
    return fe, ucount > cfg.frontier_cap


def _union_volume(csr: CSR, cfg: EngineConfig, mask: jnp.ndarray):
    """Out-edge volume of the union frontier + would-the-union-overflow."""
    return _union_volume_deg(csr.row_ptr[1:] - csr.row_ptr[:-1], cfg, mask)


# ---------------------------------------------------------------------------
# one batched push / pull iteration
# ---------------------------------------------------------------------------


def _push_step(program: ACCProgram, csr: CSR, cfg: EngineConfig, st: BatchState,
               delta: Optional[EdgeDelta] = None) -> BatchState:
    """Union-frontier push: ONE compaction + ONE balanced edge expansion for
    the whole batch (shared src/dst/w streams), per-query masking on the
    (E, Q) update matrix, one leading-axis segment combine.

    With a streaming `delta` (DESIGN.md §8), the inserted-edge COO lanes are
    appended to the expanded edge buffer unconditionally — base CSR + delta
    overlay feed ONE segment combine, and sentinel padding keeps unused lanes
    inert — so the push path sees the overlaid graph without a CSR rebuild.
    """
    n = csr.n_nodes
    comb = program.combiner
    union = jnp.any(st.active, axis=-1)
    uids, ucount, _uovf = F.compact_mask(union[:n], cfg.frontier_cap, fill=n)
    src, dst, w, valid_e, _total = expand_frontier(csr, uids, ucount, cfg.edge_cap)
    if delta is not None:
        src = jnp.concatenate([src, delta.src])
        dst = jnp.concatenate([dst, delta.dst])
        w = jnp.concatenate([w, delta.w])
        valid_e = jnp.concatenate([valid_e, delta.src < n])

    sender = {k: v[src] for k, v in st.m.items()}        # (E, Q) row gathers
    receiver = {k: v[dst] for k, v in st.m.items()}
    upd = program.compute(sender, w[:, None], receiver)
    ident = comb.identity(upd.dtype)
    # an edge carries query q's message iff its source is in q's frontier
    eactive = st.active[src] & valid_e[:, None]
    upd = jnp.where(eactive, upd, ident)
    seg = comb.segment(upd, dst, n + 1)                  # (n+1, Q)

    tele = st.tele
    if tele is not None:
        scanned = jnp.minimum(_total, jnp.int32(cfg.edge_cap))
        if delta is not None:
            scanned = scanned + jnp.sum(delta.src < n).astype(jnp.int32)
        tele = tele.at[TELE_PUSH_EDGES].add(scanned)

    m_new, nxt, count, fe, ovf, hot = _apply_and_refilter(
        program, cfg, csr, st, seg)
    return _advance(st, m_new, nxt, count, fe, ovf, was_mode=PUSH, cfg=cfg,
                    hot=hot, tele=tele)


def _slice_partial_dense(program, comb, m, s, n, ident):
    """One ELL slice's (R, Q) row partials, every row recomputed."""
    sender = {k: v[s.nbr] for k, v in m.items()}                 # (R, W, Q)
    recv = {k: v[s.row_id][:, None, :] for k, v in m.items()}
    upd = program.compute(sender, s.wgt[..., None], recv)
    upd = jnp.where(s.nbr[..., None] == n, ident, upd)
    return comb.reduce_axis_tree(upd, axis=1)                    # (R, Q)


def _slice_partial_masked(program, comb, m, s, n, ident, hot_v, prev,
                          force_dense, cfg):
    """Frontier-aware masked pull for one slice (cfg.masked_pull).

    A row's partial can only change if one of its gathered senders changed
    last iteration (`hot_v`, the union frontier mask) — everything else is
    served from the loop-carried cache `prev`. Hot rows are stream-compacted
    into a bounded `capR` row buffer (the pull analogue of the push edge
    budget); overflow or an invalidated cache falls back to the dense pull
    for this slice. Exact for min/max programs, whose `active` masks capture
    every value change; for tol-thresholded programs sub-tolerance drift
    outside the frontier stays frozen (push-mode semantics).

    Returns (partial, dense_taken, rows_recomputed) — the trailing pair
    feeds the telemetry accumulator (ignored when telemetry is off; both
    are byproducts of values this function computes anyway).
    """
    r, w = s.nbr.shape
    capR = min(r, max(8, int(math.ceil(r * cfg.masked_pull_frac))))
    hot = jnp.any(hot_v[s.nbr], axis=1)                          # (R,)
    ids, cnt, ovf = F.compact_mask(hot, capR, fill=r)

    def dense(_prev):
        return _slice_partial_dense(program, comb, m, s, n, ident)

    def sparse(prev):
        safe = jnp.minimum(ids, r - 1)
        nbr_sel = s.nbr[safe]                                    # (capR, W)
        rid_sel = s.row_id[safe]
        sender = {k: v[nbr_sel] for k, v in m.items()}           # (capR, W, Q)
        recv = {k: v[rid_sel][:, None, :] for k, v in m.items()}
        upd = program.compute(sender, s.wgt[safe][..., None], recv)
        upd = jnp.where(nbr_sel[..., None] == n, ident, upd)
        p_sel = comb.reduce_axis_tree(upd, axis=1)               # (capR, Q)
        # invalid lanes land on a dummy row; `ids` are unique by construction
        tgt = jnp.where(jnp.arange(capR, dtype=jnp.int32) < cnt, ids, r)
        buf = jnp.concatenate([prev, jnp.zeros((1, prev.shape[1]), prev.dtype)])
        return buf.at[tgt].set(p_sel)[:r]

    dense_taken = ovf | force_dense
    rows = jnp.where(dense_taken, jnp.int32(r), cnt)
    return jax.lax.cond(dense_taken, dense, sparse, prev), dense_taken, rows


def _pull_step(
    program: ACCProgram, pack: EllPack, cfg: EngineConfig, st: BatchState, csr_for_deg: CSR
) -> BatchState:
    """Full-graph pull over the degree-bucketed ELL slices, all queries at
    once: each slice's neighbor gather is (R, W, Q) with a contiguous query
    inner dim, reduced along the width then segment-combined per vertex.
    A streaming delta rides along as one more (static-shape) slice appended
    to the pack, so insertions need no special casing here."""
    n = pack.n_nodes
    comb = program.combiner
    q = st.it.shape[0]
    ident = _ident(program, st.m)
    seg = jnp.full((n + 1, q), ident)
    # residual-push programs carry the exact changed-primary mask (st.hot);
    # everything else uses the union frontier (exact for min/max, frozen
    # sub-tol drift for thresholded pull programs)
    if not cfg.masked_pull:
        hot_v = None
    elif st.hot is not None:
        hot_v = jnp.any(st.hot, axis=-1)
    else:
        hot_v = jnp.any(st.active, axis=-1)
    pseg_new = []
    tele = st.tele
    for si, s in enumerate(pack.slices):
        if cfg.masked_pull:
            partial, dense_taken, rows = _slice_partial_masked(
                program, comb, st.m, s, n, ident, hot_v, st.pseg[si],
                st.pull_dense, cfg)
            pseg_new.append(partial)
            if tele is not None:
                w = s.nbr.shape[1]
                tele = (tele
                        .at[TELE_MASKED_DENSE].add(dense_taken.astype(jnp.int32))
                        .at[TELE_MASKED_ROWS].add(rows)
                        .at[TELE_PULL_EDGES].add(rows * jnp.int32(w)))
        else:
            partial = _slice_partial_dense(program, comb, st.m, s, n, ident)
            if tele is not None:
                tele = tele.at[TELE_PULL_EDGES].add(
                    jnp.int32(s.nbr.shape[0] * s.nbr.shape[1]))
        seg = comb.pair(seg, comb.segment(partial, s.row_id, n + 1))

    m_new, nxt, count, fe, ovf, hot = _apply_and_refilter(
        program, cfg, csr_for_deg, st, seg)
    return _advance(st, m_new, nxt, count, fe, ovf, was_mode=PULL, cfg=cfg,
                    pseg=tuple(pseg_new) if cfg.masked_pull else None,
                    hot=hot, tele=tele)


def _advance(st, m_new, nxt, count, union_fe, overflow, was_mode, cfg=None,
             pseg=None, hot=None, tele=None) -> BatchState:
    live = ~st.done
    it = st.it + jnp.where(live, 1, 0)
    q = it.shape[0]
    tr_col = jnp.minimum(st.it, st.mode_trace.shape[-1] - 1)
    tr_val = jnp.where(live, jnp.int8(was_mode), st.mode_trace[jnp.arange(q), tr_col])
    tr = st.mode_trace.at[jnp.arange(q), tr_col].set(tr_val)
    keep = st.done[None, :]
    m_merged = {k: jnp.where(keep, st.m[k], m_new[k]) for k in st.m}
    # a pull leaves fresh partial caches; a push invalidates them
    pull_dense = st.pull_dense
    if cfg is not None and cfg.masked_pull:
        pull_dense = jnp.asarray(was_mode == PUSH)
    return st._replace(
        m=m_merged,
        active=nxt,
        count=jnp.where(live, count, jnp.int32(0)),
        union_fe=union_fe,
        overflow=overflow,
        it=it,
        push_iters=st.push_iters + jnp.where(live & (was_mode == PUSH), 1, 0),
        pull_iters=st.pull_iters + jnp.where(live & (was_mode == PULL), 1, 0),
        mode_trace=tr,
        pseg=st.pseg if pseg is None else pseg,
        pull_dense=pull_dense,
        hot=st.hot if hot is None else hot,
        tele=st.tele if tele is None else tele,
    )


# ---------------------------------------------------------------------------
# consensus policy
# ---------------------------------------------------------------------------


def _consensus_mode(program: ACCProgram, cfg: EngineConfig, n_edges: int, st) -> jnp.ndarray:
    """One scalar push/pull decision for the whole batch — the JIT controller
    (paper Fig. 7 + direction-optimizing volume test) over the union stream."""
    if program.modes == "push":
        return jnp.asarray(PUSH)
    if program.modes == "pull":
        return jnp.asarray(PULL)
    heavy = (
        st.overflow
        | (st.union_fe > jnp.int32(cfg.alpha * n_edges))
        | (st.union_fe > cfg.edge_cap)
    )
    return jnp.where(heavy, PULL, PUSH)


def _policy(program: ACCProgram, cfg: EngineConfig, n_edges: int, st: BatchState) -> BatchState:
    max_it = program.fixed_iters if program.fixed_iters is not None else cfg.max_iters
    done = st.done | (st.count == 0) | (st.it >= max_it)
    live = ~done
    want = _consensus_mode(program, cfg, n_edges, st)
    switched = live & (want != st.mode)
    return st._replace(
        mode=jnp.where(live, want, st.mode),
        switches=st.switches + switched.astype(jnp.int32),
        done=done,
        gmode=jnp.asarray(want, jnp.int32),
    )


def make_batched_step(program: ACCProgram, g: Graph, pack: EllPack,
                      cfg: EngineConfig, delta: Optional[EdgeDelta] = None):
    """Per-iteration batched step (BatchState -> BatchState) — used by
    `run_batch`'s fused loop and by the scheduler's host-stepped loop.
    `delta` is the streaming insertion overlay for the push path; the pull
    path reads insertions from the delta slice appended to `pack`."""

    def step(st: BatchState) -> BatchState:
        if program.modes == "push":
            new = _push_step(program, g.out, cfg, st, delta)
        elif program.modes == "pull":
            new = _pull_step(program, pack, cfg, st, g.out)
        else:
            new = jax.lax.cond(
                st.gmode == PULL,
                lambda s: _pull_step(program, pack, cfg, s, g.out),
                lambda s: _push_step(program, g.out, cfg, s, delta),
                st,
            )
        if st.tele is not None and st.tele.shape[0] > TELE_LEN:
            # single-device per-shard plane: mirror this iteration's scan
            # volume into the (only) shard slot so tele[TELE_LEN:] always
            # equals the per-shard decomposition of the global counters
            inc = new.tele - st.tele
            scan = inc[TELE_PUSH_EDGES] + inc[TELE_PULL_EDGES]
            new = new._replace(tele=new.tele.at[TELE_LEN].add(scan))
        return _policy(program, cfg, g.n_edges, new)

    return step


# ---------------------------------------------------------------------------
# init / run
# ---------------------------------------------------------------------------


def init_batch(program: ACCProgram, g: Graph, cfg: EngineConfig,
               sources, done=None, pack: Optional[EllPack] = None,
               check_caps: bool = True,
               delta: Optional[EdgeDelta] = None,
               deg: Optional[jnp.ndarray] = None,
               telemetry: bool = False,
               tele_shards: int = 1) -> BatchState:
    """Stack Q fresh query states (one per source), vertex-major.

    `done` marks lanes to create as empty/inactive (the scheduler starts
    pools fully inactive and admits into lanes later). `pack` is required
    when `cfg.masked_pull` is set (the partial caches are sized per slice).
    `check_caps=False` skips the push-only no-overflow assertion for
    engines whose push path cannot truncate (the edge-partitioned scan,
    serving/sharded.py, never consults the frontier/edge budgets — its
    compaction buffer falls back to the dense shard scan on overflow).
    `delta` is the streaming insertion overlay — init only needs it for live
    degree counts (csr.live_degrees), so degree-normalizing programs see the
    overlaid topology's degrees; `deg` passes a precomputed live-degree
    vector instead (the O(m) count is constant per graph version, so the
    per-admission hot path supplies the pool's cached one rather than
    recounting every edge per admitted lane).

    `telemetry=True` seeds the cumulative `tele` counter vector (layout in
    repro/obs) that the steps then maintain; the default leaves `tele=None`
    — no extra loop-carried state, no extra ops (DESIGN.md §12).
    `tele_shards` sizes the trailing per-shard scan-volume plane
    (DESIGN.md §14): 1 on a single device, the 'data' extent for replicated
    pools, the 'model' extent for edge-sharded pools.

    `g` may be a bare :class:`GraphDims` (with `deg` required) on the
    CSR-free path: everything init computes from the adjacency — the union
    out-edge volume and the live degrees — then comes from `deg` alone, so
    edge-partitioned admissions never touch a replicated CSR. Note the two
    volume sources differ on an overlay: the CSR path counts row_ptr SLOTS
    (deletion-neutralized slots included), the deg path counts live edges —
    which is also what the edge-sharded loop body measures, so CSR-free
    pools see consistent volumes at admission and in-loop.
    """
    csr_free = isinstance(g, GraphDims)
    assert not csr_free or deg is not None, (
        "CSR-free init needs a precomputed live-degree vector")
    sources = jnp.asarray(sources, jnp.int32)
    q = sources.shape[0]
    n = g.n_nodes
    if program.modes == "push" and check_caps:
        # same no-overflow contract as engine.init_state: a push-only program
        # has no pull fallback, so a truncated union expansion would silently
        # drop updates (the consensus controller only reroutes modes='both').
        assert cfg.frontier_cap >= n and cfg.edge_cap >= g.n_edges, (
            "push-only programs must not overflow "
            "(set frontier_cap>=n, edge_cap>=m)"
        )
    if deg is None:
        deg = live_degrees(g.out, delta)
    if _accepts_source(program):
        m_q, f_q = jax.vmap(lambda s: program.init(n, deg, source=s))(sources)
        m = {k: v.T for k, v in m_q.items()}                 # (n+1, Q)
    else:
        # source-free program (e.g. global pagerank): one init, every lane
        # identical — sources are ignored.
        m_1, f_1 = program.init(n, deg)
        m = {k: jnp.broadcast_to(v[:, None], (n + 1, q)) for k, v in m_1.items()}
        f_q = jnp.broadcast_to(f_1[None, :], (q,) + f_1.shape)
    mask = jnp.zeros((n + 1, q), bool)
    lane = jnp.broadcast_to(jnp.arange(q, dtype=jnp.int32)[:, None], f_q.shape)
    mask = mask.at[f_q.astype(jnp.int32), lane].set(True, mode="drop")
    mask = mask.at[-1].set(False)
    if done is None:
        done = jnp.zeros((q,), bool)
    done = jnp.asarray(done)
    mask = mask & ~done[None, :]
    count = jnp.sum(mask, axis=0).astype(jnp.int32)
    if csr_free:
        union_fe, overflow = _union_volume_deg(deg, cfg, mask)
    else:
        union_fe, overflow = _union_volume(g.out, cfg, mask)
    if cfg.masked_pull and pack is not None:
        dt = m[program.primary].dtype
        ident = program.combiner.identity(dt)
        pseg = tuple(jnp.full((s.nbr.shape[0], q), ident) for s in pack.slices)
        pull_dense = jnp.asarray(True)
        # residual-push programs track exact staleness; start all-hot (the
        # first pull is dense anyway and refills every cached partial)
        hot = (jnp.ones((n + 1, q), bool)
               if program.param("kind") == "residual" else None)
    else:
        pseg, pull_dense, hot = (), None, None
    st = BatchState(
        m=m, active=mask, count=count, union_fe=union_fe, overflow=overflow,
        mode=jnp.full((q,), PUSH, jnp.int32),
        it=jnp.zeros((q,), jnp.int32),
        done=done | (count == 0),
        push_iters=jnp.zeros((q,), jnp.int32),
        pull_iters=jnp.zeros((q,), jnp.int32),
        switches=jnp.zeros((q,), jnp.int32),
        mode_trace=jnp.full((q, cfg.trace_len), -1, jnp.int8),
        gmode=jnp.asarray(PUSH, jnp.int32),
        pseg=pseg,
        pull_dense=pull_dense,
        hot=hot,
        tele=(jnp.zeros((TELE_LEN + int(tele_shards),), jnp.int32)
              if telemetry else None),
    )
    return st._replace(gmode=_consensus_mode(program, cfg, g.n_edges, st),
                       mode=jnp.where(st.done, st.mode,
                                      _consensus_mode(program, cfg, g.n_edges, st)))


@functools.partial(jax.jit, static_argnums=(0, 3))
def _run_fused(program, g, pack, cfg, st0, delta=None):
    step = make_batched_step(program, g, pack, cfg, delta)
    return jax.lax.while_loop(lambda s: jnp.any(~s.done), step, st0)


def run_state(
    program: ACCProgram,
    g: Graph,
    pack: EllPack,
    cfg: EngineConfig,
    st0: BatchState,
    delta: Optional[EdgeDelta] = None,
    fusion: str = "all",
):
    """Advance an existing :class:`BatchState` to convergence. The streaming
    subsystem enters here with a state seeded from a previous fixpoint
    (incremental recomputation, DESIGN.md §8); `run_batch` enters with a
    fresh state. Returns (metadata dict, stats)."""
    if fusion == "all":
        final = _run_fused(program, g, pack, cfg, st0, delta)
    elif fusion == "none":
        step = jax.jit(make_batched_step(program, g, pack, cfg, delta))
        final = st0
        while bool(jnp.any(~final.done)):
            final = step(final)
    else:
        raise ValueError(fusion)
    stats = {
        "iterations": jnp.max(final.it),
        "per_query_iters": final.it,
        "push_iters": final.push_iters,
        "pull_iters": final.pull_iters,
        "switches": final.switches,
        "final_count": final.count,
        "mode_trace": final.mode_trace,
        "tele": final.tele,
    }
    return final.m, stats


def run_batch(
    program: ACCProgram,
    g: Graph,
    pack: EllPack,
    cfg: EngineConfig,
    sources,
    fusion: str = "all",
    delta: Optional[EdgeDelta] = None,
    telemetry: bool = False,
):
    """Run Q point queries of `program` (one per entry of `sources`) to
    convergence as one batch. Returns (metadata dict, field -> (n+1, Q),
    stats). `cfg.pull_impl`/`cfg.sparse_combine` are single-query fast paths
    and are ignored here. `telemetry=True` carries the cumulative engine
    counters (stats['tele'], layout in repro/obs)."""
    st0 = init_batch(program, g, cfg, sources, pack=pack, delta=delta,
                     telemetry=telemetry)
    return run_state(program, g, pack, cfg, st0, delta=delta, fusion=fusion)


def query_result(m: dict, field: str, lane: int) -> jnp.ndarray:
    """Extract lane `lane`'s (n,) result from vertex-major batched metadata."""
    return m[field][:-1, lane]


def run_sequential(program_factory, g: Graph, pack: EllPack, cfg: EngineConfig,
                   sources, run_fn=None):
    """Reference: the same queries one at a time through the single-query
    engine. Used by tests to assert bit-identity and by benchmarks as the
    no-batching baseline."""
    from repro.core import engine as E

    run_fn = run_fn or E.run
    outs = []
    for s in sources:
        m, _ = run_fn(program_factory(), g, pack, cfg, source=jnp.int32(int(s)))
        outs.append(m)
    return outs
