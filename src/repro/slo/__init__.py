"""`repro.slo` — SLO-grade serving: open-loop load + deadline policy.

Two halves (DESIGN.md §13):

  workload.py -- seeded open-loop arrival specs: Poisson / bursty (MMPP)
                 clocks, multi-tenant algorithm mixes with per-class
                 deadlines and source skew, interleaved streaming update
                 batches; `generate` expands a spec deterministically.
  harness.py  -- `replay`: fire the arrival list at a `GraphServer` on the
                 wall clock WITHOUT closing the loop on completions, then
                 report goodput, shed/drop/degrade/preempt counts, and
                 p50/p95/p99 latency.

The enforcement half lives inside the serving stack (`repro.serving.slo`,
re-exported here): `SLOPolicy` drives admission-time drops, degraded
shadow pools, and lane preemption/resume; consensus cohorts
(`GraphServer(cohorts=...)`) give tail isolation. `benchmarks/slo_bench.py`
ties both halves together into BENCH_slo.json.
"""

from repro.serving.slo import SLOPolicy, degraded_variant  # noqa: F401
from repro.slo.harness import (  # noqa: F401
    ReplayReport,
    percentiles,
    replay,
    warmup,
)
from repro.slo.workload import (  # noqa: F401
    Arrival,
    TenantClass,
    Workload,
    describe,
    generate,
)

__all__ = [
    "SLOPolicy",
    "degraded_variant",
    "Workload",
    "TenantClass",
    "Arrival",
    "generate",
    "describe",
    "replay",
    "warmup",
    "ReplayReport",
    "percentiles",
]
