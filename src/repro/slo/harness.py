"""Open-loop replay: fire a workload's arrival list at a `GraphServer`.

The loop is strictly open: each arrival is submitted when its spec time
comes due on the host wall clock, whether or not earlier queries have
completed — queue backpressure surfaces as SHED submissions (quota-full
`submit` returning None), never as a slowed arrival clock. Between
arrivals the loop pumps the server continuously; after the last arrival it
drains. The report separates every way a query can leave the system:

    completed      engine- or cache-served with a result
    shed           refused at submit (queue share full, open-loop overrun)
    dropped        policy-shed (expired/hopeless deadline), result=None
    deadline_missed completed but late (also counts every drop)
    degraded       served from the loosened-tolerance shadow pool
    preempted      evicted mid-run at least once before completing

Goodput is the fraction of OFFERED queries that produced a timely answer:
(completed - deadline_missed-but-completed) / offered, with best-effort
(deadline-less) completions counting as good. Percentiles are measured on
the harness's own wall clock (submit->completion observed), independent of
the server's span telemetry, so the harness works with telemetry off.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.serving.scheduler import GraphServer
from repro.slo.workload import Arrival


#: floor on reported latencies: synchronous completions (cache hits,
#: expired-at-submit drops) cost ~0 wall time but the bench schema pins
#: every *_seconds as strictly positive
EPS_S = 1e-9


def percentiles(samples: List[float]) -> Optional[dict]:
    """{p50,p95,p99,mean}_seconds over raw latency samples (None if empty).
    np.percentile with linear interpolation — same convention as the
    closed-loop benches."""
    if not samples:
        return None
    arr = np.asarray(samples, np.float64)
    return {
        "n": int(arr.size),
        "mean_seconds": max(float(arr.mean()), EPS_S),
        "p50_seconds": max(float(np.percentile(arr, 50)), EPS_S),
        "p95_seconds": max(float(np.percentile(arr, 95)), EPS_S),
        "p99_seconds": max(float(np.percentile(arr, 99)), EPS_S),
    }


@dataclasses.dataclass
class ReplayReport:
    offered: int
    completed: int
    good: int
    shed: int
    dropped: int
    degraded: int
    preempted: int
    deadline_missed: int
    cache_hits: int
    updates_applied: int
    goodput: float
    wall_s: float
    #: lanes still holding a rid after the drain — MUST be 0 (a non-zero
    #: count means the scheduler leaked/wedged a lane under load)
    crashed_lanes: int
    total: Optional[dict]                  # percentiles over all completions
    per_algo: Dict[str, Optional[dict]]
    per_tenant: Dict[str, Optional[dict]]
    #: server-side streaming health snapshot (stats()["health"]) taken at
    #: drain — P² quantiles + windowed miss/goodput gauges (DESIGN.md §14).
    #: {"enabled": False} when the server runs without a health monitor.
    health: Optional[dict] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def replay(srv: GraphServer, arrivals: List[Arrival], *,
           max_wall_s: Optional[float] = None) -> ReplayReport:
    """Open-loop replay of `arrivals` (from `workload.generate`) against a
    server, then drain; see module docstring for the report's accounting.
    Counters (slo_counts, rejected, cache hits, updates) are reported as
    DELTAS over the replay, so a warmed-up server replays cleanly."""
    slo0 = dict(srv.slo_counts)
    updates0 = len(srv.update_log)
    # P² markers can't be delta'd like the counters above: reset so warmup
    # JIT-compile latencies never poison the measured-phase quantiles
    srv.obs.health.reset()
    t0 = time.monotonic()
    sub_t: Dict[int, float] = {}          # rid -> submit wall time
    comp_t: Dict[int, float] = {}         # rid -> completion wall time
    shed = 0
    i = 0
    deadline = None if max_wall_s is None else t0 + max_wall_s

    def pump_and_stamp() -> None:
        now = time.monotonic()
        for c in srv.pump():
            comp_t.setdefault(c.rid, now)

    while True:
        now = time.monotonic() - t0
        while i < len(arrivals) and arrivals[i].t <= now:
            a = arrivals[i]
            i += 1
            if a.kind == "update":
                srv.apply_updates(inserts=list(a.inserts),
                                  deletes=list(a.deletes))
                continue
            rid = srv.submit(a.algo, a.source, tenant=a.tenant,
                             deadline_ms=a.deadline_ms)
            if rid is None:
                shed += 1
            else:
                # synchronous completions (cache hit, expired-at-submit
                # drop) never get a pump stamp; collection falls back to
                # the submit time (latency ~0, which is what they cost)
                sub_t[rid] = time.monotonic()
        pump_and_stamp()
        busy = (srv._queued() > 0
                or any(p.live() for _n, p, _d in srv._leaves()))
        if i >= len(arrivals) and not busy:
            break
        if deadline is not None and time.monotonic() > deadline:
            break
        if not busy and i < len(arrivals):
            # idle gap before the next arrival: sleep instead of spinning
            gap = arrivals[i].t - (time.monotonic() - t0)
            if gap > 0:
                time.sleep(min(gap, 0.002))
    wall_s = time.monotonic() - t0

    by_rid = {c.rid: c for c in srv.completions if c.rid in sub_t}
    lat_all: List[float] = []
    lat_algo: Dict[str, List[float]] = {}
    lat_tenant: Dict[str, List[float]] = {}
    completed = good = missed = cache_hits = 0
    for rid, c in by_rid.items():
        if c.dropped:
            continue
        completed += 1
        if c.from_cache:
            cache_hits += 1
        if c.deadline_missed:
            missed += 1
        else:
            good += 1
        lat = max(0.0, comp_t.get(rid, sub_t[rid]) - sub_t[rid])
        lat_all.append(lat)
        lat_algo.setdefault(c.algo, []).append(lat)
        lat_tenant.setdefault(c.tenant, []).append(lat)
    offered = len(sub_t) + shed
    slo_d = {k: srv.slo_counts[k] - slo0[k] for k in slo0}
    crashed = sum(
        1 for _n, p, _d in srv._leaves() for r in p.lane_rid if r is not None)
    if crashed and srv.obs.flight is not None:
        # post-mortem: a wedged lane is exactly what the flight recorder
        # exists for — dump the event ring before anyone resets the server
        crash_path = "/tmp/repro_flight_crash.jsonl"
        srv.obs.flight.record("crash", crashed_lanes=int(crashed))
        n = srv.dump_flight_record(crash_path)
        print(f"[replay] {crashed} crashed lane(s): flight record "
              f"({n} events) -> {crash_path}")
    return ReplayReport(
        offered=offered,
        completed=completed,
        good=good,
        shed=shed,
        dropped=slo_d["dropped"],
        degraded=slo_d["degraded"],
        preempted=slo_d["preempted"],
        deadline_missed=slo_d["deadline_missed"],
        cache_hits=cache_hits,
        updates_applied=len(srv.update_log) - updates0,
        goodput=(good / offered) if offered else 0.0,
        wall_s=wall_s,
        crashed_lanes=crashed,
        total=percentiles(lat_all),
        per_algo={a: percentiles(ls) for a, ls in sorted(lat_algo.items())},
        per_tenant={t: percentiles(ls)
                    for t, ls in sorted(lat_tenant.items())},
        health=srv.stats().get("health"),
    )


def warmup(srv: GraphServer, algo_sources: Dict[str, int]) -> None:
    """Compile-warm a server before a measured replay: one query per
    algorithm pool (drained), plus one forced admission through each
    degraded shadow pool so its first JIT compile doesn't land inside the
    measurement window. Uses real scheduler paths; counter deltas are the
    caller's concern (`replay` snapshots at entry)."""
    tenant0 = next(iter(srv.tenants))
    for algo, src in algo_sources.items():
        srv.submit(algo, src, tenant=tenant0)
    srv.drain()
    for name, dp in srv.degraded_pools.items():
        src = algo_sources.get(name, 0)
        rid = srv._next_rid
        srv._next_rid += 1
        srv.obs.tracer.begin(rid, name, src, next(iter(srv.tenants)),
                             srv.graph_version)
        srv._inflight_sources[rid] = src
        srv._inflight_tenants[rid] = next(iter(srv.tenants))
        dp.admit(dp.free_lanes()[0], rid, src)
        srv.obs.tracer.mark(rid, "admit")
        srv._degraded_rids.add(rid)
        srv.drain()
    # warmup results must not serve the measured replay from cache
    srv.cache.clear()
    # ... and warmup residencies must not poison the EWMA service-time
    # estimate: the first query per pool pays its JIT compile (seconds) in
    # residency, which would make every deadline look hopeless to
    # SLOPolicy.hopeless_margin and over-trigger preemption slack
    for _name, pool, _deg in srv._leaves():
        pool.ewma_resident_s = None
