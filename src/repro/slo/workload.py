"""Seeded open-loop workload specs for the serving stack (DESIGN.md §13).

A `Workload` describes an arrival PROCESS, not a request list: multi-tenant
mixes of ACC queries (each tenant class owns a weight, an algorithm mix, a
deadline, and a source skew) arriving by a Poisson or bursty (2-state
MMPP — Markov-modulated Poisson) clock, with streaming edge-update batches
interleaved at a fixed cadence. `generate(workload, n_nodes)` expands it
deterministically (one `numpy` Generator, fixed draw order) into a sorted
`Arrival` list that `repro.slo.harness.replay` fires at the server
open-loop — submission times come from the spec's clock, never from
completions, which is what makes overload visible instead of self-throttled
(closed-loop benches like BENCH_obs.json can never overrun the server).

The MMPP burst model: the process alternates between a LOW state and a HIGH
state (rate = `rate_qps * burst_factor`) with exponentially distributed
dwell times, tuned so a `burst_frac` fraction of time is spent bursting and
the time-averaged rate stays ~`rate_qps`. Bursts are what defeat
average-rate provisioning — the queue depth a burst builds is exactly what
the SLO policy's drop/degrade/preempt triggers act on.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One tenant population's traffic contract."""

    tenant: str = "default"
    #: share of the arrival stream routed to this tenant
    weight: float = 1.0
    #: (algo, weight) mix of query types this tenant issues
    algos: Tuple[Tuple[str, float], ...] = (("bfs", 1.0),)
    #: latency SLO attached to every query (None = best-effort)
    deadline_ms: Optional[float] = None
    #: fraction of queries aimed at the shared hot source set (cacheable
    #: skew); the rest draw uniformly over all nodes
    hot_frac: float = 0.0
    #: explicit source pool overriding the uniform draw (e.g. hub vertices
    #: for a deliberately heavy tenant); hot_frac still applies first
    sources: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass(frozen=True)
class Workload:
    """Seeded open-loop arrival spec. `arrival` is 'poisson' (homogeneous)
    or 'mmpp' (bursty two-state, see module docstring)."""

    arrival: str = "poisson"
    #: time-averaged arrival rate (both processes target this mean)
    rate_qps: float = 50.0
    duration_s: float = 5.0
    #: HIGH-state rate multiplier (mmpp only)
    burst_factor: float = 6.0
    #: fraction of time spent in the HIGH state (mmpp only)
    burst_frac: float = 0.25
    #: mean HIGH-state dwell (mmpp only); LOW dwell follows from burst_frac
    burst_dwell_s: float = 0.4
    tenants: Tuple[TenantClass, ...] = (TenantClass(),)
    #: cadence of interleaved streaming edge-update batches (0 = none)
    update_every_s: float = 0.0
    #: edges inserted per update batch (plus a few deletions of earlier
    #: inserted edges, exercising both overlay directions)
    update_batch: int = 8
    #: size of the shared hot source set `hot_frac` draws from
    hot_set: int = 16
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One expanded event: a query submission or an update batch."""

    t: float
    kind: str                       # 'query' | 'update'
    algo: str = ""
    source: int = 0
    tenant: str = "default"
    deadline_ms: Optional[float] = None
    inserts: Tuple[Tuple[int, int], ...] = ()
    deletes: Tuple[Tuple[int, int], ...] = ()


def _poisson_times(w: Workload, rng: np.random.Generator) -> List[float]:
    t, out = 0.0, []
    scale = 1.0 / w.rate_qps
    while True:
        t += rng.exponential(scale)
        if t >= w.duration_s:
            return out
        out.append(t)


def _mmpp_times(w: Workload, rng: np.random.Generator) -> List[float]:
    f = min(max(w.burst_frac, 1e-6), 1.0 - 1e-6)
    hi = w.rate_qps * w.burst_factor
    # low-state rate chosen so f*hi + (1-f)*lo == rate_qps; clamps at 0 when
    # the bursts alone carry more than the average (all-burst traffic)
    lo = max(w.rate_qps * (1.0 - f * w.burst_factor) / (1.0 - f), 0.0)
    dwell_hi = w.burst_dwell_s
    dwell_lo = dwell_hi * (1.0 - f) / f
    t, out, high = 0.0, [], False
    seg_end = rng.exponential(dwell_lo)
    while t < w.duration_s:
        rate = hi if high else lo
        nxt = t + rng.exponential(1.0 / rate) if rate > 0 else seg_end
        if nxt >= seg_end:
            t = seg_end
            high = not high
            seg_end = t + rng.exponential(dwell_hi if high else dwell_lo)
        else:
            t = nxt
            if t < w.duration_s:
                out.append(t)
    return out


def generate(w: Workload, n_nodes: int) -> List[Arrival]:
    """Expand a workload spec into its sorted arrival list. Deterministic
    per (spec, n_nodes): one seeded Generator, fixed consumption order
    (arrival clock, then per-query draws in arrival order, then updates)."""
    assert w.arrival in ("poisson", "mmpp"), w.arrival
    assert w.tenants, "workload needs at least one tenant class"
    rng = np.random.default_rng(w.seed)
    times = (_poisson_times(w, rng) if w.arrival == "poisson"
             else _mmpp_times(w, rng))
    hot = rng.integers(0, n_nodes, size=max(1, w.hot_set))

    tw = np.asarray([tc.weight for tc in w.tenants], np.float64)
    tw = tw / tw.sum()
    out: List[Arrival] = []
    for t in times:
        tc = w.tenants[int(rng.choice(len(w.tenants), p=tw))]
        aw = np.asarray([a[1] for a in tc.algos], np.float64)
        algo = tc.algos[int(rng.choice(len(tc.algos), p=aw / aw.sum()))][0]
        if tc.hot_frac > 0 and rng.random() < tc.hot_frac:
            source = int(hot[int(rng.integers(0, len(hot)))])
        elif tc.sources is not None:
            source = int(tc.sources[int(rng.integers(0, len(tc.sources)))])
        else:
            source = int(rng.integers(0, n_nodes))
        out.append(Arrival(t=float(t), kind="query", algo=algo,
                           source=source, tenant=tc.tenant,
                           deadline_ms=tc.deadline_ms))
    if w.update_every_s > 0:
        inserted: List[Tuple[int, int]] = []
        k = 1
        while k * w.update_every_s < w.duration_s:
            ins = [(int(u), int(v)) for u, v in zip(
                rng.integers(0, n_nodes, size=w.update_batch),
                rng.integers(0, n_nodes, size=w.update_batch)) if u != v]
            n_del = min(len(inserted), max(0, w.update_batch // 4))
            dels = [inserted.pop(int(rng.integers(0, len(inserted))))
                    for _ in range(n_del)]
            inserted.extend(ins)
            out.append(Arrival(t=float(k * w.update_every_s), kind="update",
                               inserts=tuple(ins), deletes=tuple(dels)))
            k += 1
    out.sort(key=lambda a: (a.t, a.kind))   # 'query' < 'update' at a tie
    return out


def describe(w: Workload) -> dict:
    """JSON-able spec summary for bench records."""
    return {
        "arrival": w.arrival,
        "rate_qps": w.rate_qps,
        "duration_s": w.duration_s,
        "burst_factor": w.burst_factor if w.arrival == "mmpp" else None,
        "burst_frac": w.burst_frac if w.arrival == "mmpp" else None,
        "seed": w.seed,
        "update_every_s": w.update_every_s,
        "tenants": [
            {"tenant": tc.tenant, "weight": tc.weight,
             "algos": [list(a) for a in tc.algos],
             "deadline_ms": tc.deadline_ms, "hot_frac": tc.hot_frac}
            for tc in w.tenants
        ],
    }
