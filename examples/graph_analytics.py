"""Graph-analytics pipeline: the paper's full algorithm suite over the graph
zoo, with the Fig.8-style JIT-management report — the 'Table 4' user journey.

  PYTHONPATH=src python examples/graph_analytics.py
"""

import time

import numpy as np

from repro.core import algorithms as A
from repro.core.engine import EngineConfig, run
from repro.graph import generators, pack_ell
from repro.graph.packing import pack_stats


def main():
    graphs = {
        "social (rmat 4k)": generators.rmat(12, 8, seed=1),
        "road (grid 64x64)": generators.grid2d(64, seed=5),
    }
    algos = {
        "bfs": lambda: A.bfs(0),
        "sssp": lambda: A.sssp(0),
        "wcc": lambda: A.wcc(),
        "pagerank": lambda: A.pagerank(max_iters=32),
        "kcore(k=8)": lambda: A.kcore(k=8),
        "bp": lambda: A.belief_propagation(n_iters=8),
    }
    for gname, g in graphs.items():
        pack = pack_ell(g.inc)
        st = pack_stats(pack)
        fill = {k: round(v["fill"], 2) for k, v in st.items()}
        print(f"\n== {gname}: {g.n_nodes} vertices, {g.n_edges} edges")
        print(f"   ELL buckets fill: {fill}")
        cfg = EngineConfig(frontier_cap=g.n_nodes, edge_cap=g.n_edges)
        for aname, mk in algos.items():
            t0 = time.time()
            md, stats = run(mk(), g, pack, cfg)
            dt = (time.time() - t0) * 1e3
            tr = np.asarray(stats["mode_trace"])[: int(stats["iterations"])]
            print(f"   {aname:12s} {dt:8.1f} ms  iters={int(stats['iterations']):4d} "
                  f"push={int(stats['push_iters']):4d} pull={int(stats['pull_iters']):3d} "
                  f"switches={int(stats['switches'])}")


if __name__ == "__main__":
    main()
