"""End-to-end LM training driver: trains a transformer from the assigned
config family for a few hundred steps with the full production substrate
(checkpoint/resume, preemption guard, watchdog, cosine schedule).

Default preset is CPU-sized; `--preset 100m --steps 300` is the paper-scale
run used on real hardware (same code path).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import sys

from repro.launch.train import main as train_main


if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--arch") for a in args):
        args = ["--arch", "granite-3-8b"] + args
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "200"]
    if not any(a.startswith("--ckpt-dir") for a in args):
        args += ["--ckpt-dir", "/tmp/repro_train_lm"]
    raise SystemExit(train_main(args))
