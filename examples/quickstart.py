"""Quickstart: write a graph algorithm in ~10 lines of ACC and run it on the
SIMD-X engine (the paper's headline: 'tens of lines of code').

Here: single-source widest-path (maximin bottleneck) — an algorithm NOT in
the paper, defined from scratch with Active/Compute/Combine to show the
model's expressiveness.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.acc import ACCProgram, Combiner
from repro.core.engine import EngineConfig, run
from repro.graph import generators, pack_ell


def widest_path(src: int) -> ACCProgram:
    """width[v] = max over paths of the min edge weight along the path."""

    def init(n, deg, source=src):
        w0 = jnp.zeros((n + 1,), jnp.float32).at[source].set(jnp.inf)
        return {"width": w0}, jnp.asarray([source])

    # Compute: the width through edge (u -> v) is min(width[u], w_uv)
    def compute(sender, w, receiver):
        return jnp.minimum(sender["width"], w)

    # Combine: keep the MAX candidate width per destination
    def active(new, old, it):
        return new["width"] > old["width"]

    return ACCProgram(
        name="widest_path",
        combiner=Combiner("max", "aggregation"),
        init=init, compute=compute, active=active, primary="width",
    )


def main():
    g = generators.rmat(11, 8, seed=7)           # 2048-node power-law graph
    pack = pack_ell(g.inc)
    cfg = EngineConfig(frontier_cap=g.n_nodes, edge_cap=g.n_edges)
    md, stats = run(widest_path(0), g, pack, cfg)

    width = np.asarray(md["width"][: g.n_nodes])
    reached = np.isfinite(width) & (width > 0)
    print(f"graph: {g.n_nodes} vertices / {g.n_edges} edges")
    print(f"iterations: {int(stats['iterations'])} "
          f"(push {int(stats['push_iters'])}, pull {int(stats['pull_iters'])}, "
          f"{int(stats['switches'])} JIT filter switches)")
    print(f"reachable: {int(reached.sum())} vertices; "
          f"median bottleneck width {np.median(width[reached]):.0f}")

    # sanity: verify against a numpy maximin Dijkstra
    rp, ci, w = (np.asarray(g.out.row_ptr), np.asarray(g.out.col_idx),
                 np.asarray(g.out.weights))
    import heapq

    exp = np.zeros(g.n_nodes)
    exp[0] = np.inf
    h = [(-np.inf, 0)]
    while h:
        negw, v = heapq.heappop(h)
        if -negw < exp[v]:
            continue
        for e in range(rp[v], rp[v + 1]):
            u, cand = ci[e], min(exp[v], w[e])
            if cand > exp[u]:
                exp[u] = cand
                heapq.heappush(h, (-cand, u))
    ok = np.allclose(np.where(np.isinf(width), np.inf, width),
                     np.where(np.isinf(exp), np.inf, exp))
    print("matches numpy maximin-dijkstra:", ok)
    assert ok


if __name__ == "__main__":
    main()
