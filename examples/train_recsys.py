"""DeepFM CTR training on the synthetic click stream + retrieval scoring.

  PYTHONPATH=src python examples/train_recsys.py --steps 100
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data import ClickStream
from repro.models import deepfm as dfm
from repro.optim import AdamWConfig, init as opt_init, update as opt_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=1024)
    args = ap.parse_args()

    cfg = configs.get("deepfm").make_reduced()
    stream = ClickStream(cfg.n_fields, cfg.vocab_per_field, cfg.embed_dim,
                         batch=args.batch, seed=0)
    params = dfm.init_params(jax.random.key(0), cfg)
    ocfg = AdamWConfig(lr=3e-3, weight_decay=1e-6, total_steps=args.steps)
    opt = opt_init(params, ocfg)

    @jax.jit
    def step(p, o, ids, y):
        lv, g = jax.value_and_grad(dfm.loss_fn)(p, ids, y, cfg)
        p, o, m = opt_update(g, o, p, ocfg)
        return p, o, lv

    for i in range(args.steps):
        ids, y = next(stream)
        params, opt, lv = step(params, opt, jnp.asarray(ids), jnp.asarray(y))
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: bce {float(lv):.4f}")

    # AUC on a held-out batch
    ids, y = next(stream)
    scores = np.asarray(dfm.forward(params, jnp.asarray(ids), cfg))
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=float)
    ranks[order] = np.arange(len(scores))
    pos = y > 0.5
    auc = (ranks[pos].mean() - (pos.sum() - 1) / 2) / max((~pos).sum(), 1)
    print(f"held-out AUC: {auc:.3f}")

    # retrieval: score one user against 100k candidates (one matmul)
    cand = np.asarray(params["table"][: 100_000 % params["table"].shape[0] + 1000])
    uv = dfm.user_vector(params, jnp.asarray(ids[:1]), cfg)
    top = jax.lax.top_k(dfm.score_candidates(uv, jnp.asarray(cand)), 5)
    print("top-5 candidate ids:", np.asarray(top[1])[0].tolist())


if __name__ == "__main__":
    main()
