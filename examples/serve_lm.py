"""Batched LM serving with continuous batching (prefill + decode slots).

  PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 3
"""

import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    raise SystemExit(serve_main(sys.argv[1:]))
